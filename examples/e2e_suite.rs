//! End-to-end validation driver (DESIGN.md §4, experiment H1).
//!
//! Exercises the full system on the real (simulated-substrate) workload
//! suite, proving all layers compose: dataset generation (L3 simulator) →
//! AOT artifact loading (PJRT runtime, L2/L1) → the full optimizer suite →
//! regret grids for Figures 2/3 → the §IV-E savings analysis → headline
//! shape checks against the paper's claims. Writes CSVs to results/e2e/
//! and prints a paper-vs-measured summary (recorded in EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example e2e_suite            # ~minutes (6 seeds)
//! E2E_SEEDS=50 cargo run --release --example e2e_suite   # paper-scale
//! ```

use multicloud::coordinator::experiment::RegretGrid;
use multicloud::coordinator::savings::{savings_analysis, SavingsConfig};
use multicloud::dataset::{OfflineDataset, Target, BOTH_TARGETS};
use multicloud::report::figures;
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let seeds = env_usize("E2E_SEEDS", 6);
    let out_dir = "results/e2e";
    std::fs::create_dir_all(out_dir).ok();
    let started = std::time::Instant::now();

    println!("=== multicloud end-to-end suite (seeds={seeds}) ===\n");

    // -- 1. dataset ---------------------------------------------------------
    let ds = OfflineDataset::generate(2022, 5);
    std::fs::write(format!("{out_dir}/offline.csv"), ds.to_csv()).unwrap();
    println!(
        "[1/5] dataset: {} workloads x {} configs x {} reps -> {out_dir}/offline.csv",
        ds.workload_count(),
        ds.domain.size(),
        ds.reps
    );

    // -- 2. runtime ------------------------------------------------------
    // The PJRT artifacts are loaded and spot-checked against the native
    // surrogates here (full parity suite: rust/tests/artifact_parity.rs).
    // The large regret grids below then run on the native backend: at
    // these problem sizes (n <= 88, d = 20) fixed PJRT dispatch overhead
    // dominates (see EXPERIMENTS.md §Perf), and this host has one core.
    match ArtifactBackend::load(&artifact_dir(None)) {
        Ok(b) => {
            let grid88 = ds.domain.full_grid();
            let rows: Vec<Vec<f64>> =
                grid88.iter().map(|c| multicloud::domain::encode(&ds.domain, c)).collect();
            let enc = multicloud::linalg::Matrix::from_rows(&rows);
            let x = multicloud::linalg::Matrix::from_rows(&rows[..16]);
            let y: Vec<f64> = (0..16).map(|i| ds.mean_value(0, i, Target::Cost)).collect();
            let pa = b.gp_fit_predict(&x, &y, &enc);
            let pn = NativeBackend.gp_fit_predict(&x, &y, &enc);
            let max_dev = pa
                .mean
                .iter()
                .zip(&pn.mean)
                .map(|(a, n)| (a - n).abs())
                .fold(0.0f64, f64::max);
            println!(
                "[2/5] backend: PJRT artifacts loaded (N={}, M={}, D={});                  GP posterior parity vs native: max |dev| = {max_dev:.2e}",
                b.manifest.n_max, b.manifest.m_max, b.manifest.d
            );
            assert!(max_dev < 1e-2, "artifact/native divergence");
        }
        Err(e) => println!("[2/5] backend: PJRT artifacts unavailable ({e})"),
    }
    let backend: Box<dyn Backend + Send + Sync> = Box::new(NativeBackend);

    // -- 3. regret grids (Figures 2 + 3) -------------------------------------
    let fig2_methods =
        ["predict-linear", "predict-rf", "rs", "cherrypick-x1", "cherrypick-x3", "bilal-x1", "bilal-x3"];
    let fig3_methods =
        ["rs", "cherrypick-x1", "cherrypick-x3", "smac", "hyperopt", "rb", "cb-cherrypick", "cb-rbfopt"];
    let mut all_curves = Vec::new();
    for (name, methods) in [("fig2", &fig2_methods[..]), ("fig3", &fig3_methods[..])] {
        let t0 = std::time::Instant::now();
        let mut grid = RegretGrid::new(&ds, backend.as_ref());
        grid.methods = methods.iter().map(|m| m.to_string()).collect();
        grid.seeds = seeds;
        grid.verbose = false;
        let curves = grid.run();
        std::fs::write(format!("{out_dir}/{name}.csv"), figures::regret_csv(&curves)).unwrap();
        println!(
            "[3/5] {name}: {} methods x 8 budgets x 2 targets x 30 workloads x {seeds} seeds in {:.1}s",
            methods.len(),
            t0.elapsed().as_secs_f64()
        );
        all_curves.push((name, curves));
    }

    // -- 4. savings (Figure 4) ------------------------------------------------
    let t0 = std::time::Instant::now();
    let cfg = SavingsConfig { seeds, ..Default::default() };
    let methods: Vec<String> =
        ["smac", "cb-rbfopt", "rs", "exhaustive"].iter().map(|m| m.to_string()).collect();
    let mut dists = Vec::new();
    for target in BOTH_TARGETS {
        dists.extend(savings_analysis(&ds, backend.as_ref(), &methods, target, &cfg));
    }
    std::fs::write(format!("{out_dir}/fig4.csv"), figures::savings_csv(&ds, &dists)).unwrap();
    println!("[4/5] fig4 savings (B=33, N=64) in {:.1}s\n", t0.elapsed().as_secs_f64());
    for target in BOTH_TARGETS {
        println!("-- savings, target {} --", target.name());
        let td: Vec<_> = dists.iter().filter(|d| d.target == target).cloned().collect();
        print!("{}", figures::savings_ascii(&td));
        println!();
    }

    // -- 5. headline shape checks ---------------------------------------------
    println!("[5/5] paper-vs-measured headline checks:");
    let mut pass = 0;
    let mut fail = 0;
    let mut check = |name: &str, ok: bool, detail: String| {
        println!("  [{}] {name}: {detail}", if ok { "PASS" } else { "FAIL" });
        if ok {
            pass += 1;
        } else {
            fail += 1;
        }
    };

    // Helper: final-budget regret of a method.
    let regret_at = |curves: &[multicloud::coordinator::experiment::RegretCurve],
                     method: &str,
                     target: Target,
                     bi: usize| {
        curves
            .iter()
            .find(|c| c.method == method && c.target == target)
            .map(|c| c.mean_regret[bi])
            .unwrap()
    };

    let fig3 = &all_curves.iter().find(|(n, _)| *n == "fig3").unwrap().1;
    let fig2 = &all_curves.iter().find(|(n, _)| *n == "fig2").unwrap().1;

    // (a) Search beats predictive at large budget (both targets).
    for t in BOTH_TARGETS {
        let best_search = ["rs", "cherrypick-x1", "cherrypick-x3"]
            .iter()
            .map(|m| regret_at(fig2, m, t, 7))
            .fold(f64::INFINITY, f64::min);
        let best_pred = ["predict-linear", "predict-rf"]
            .iter()
            .map(|m| regret_at(fig2, m, t, 7))
            .fold(f64::INFINITY, f64::min);
        check(
            &format!("search < predictive at B=88 ({})", t.name()),
            best_search < best_pred,
            format!("search {best_search:.3} vs predictive {best_pred:.3}"),
        );
    }

    // (b) SMAC and CB-RBFOpt beat RS across budgets (mean over budgets).
    for t in BOTH_TARGETS {
        for m in ["smac", "cb-rbfopt"] {
            let mean_m: f64 = (0..8).map(|b| regret_at(fig3, m, t, b)).sum::<f64>() / 8.0;
            let mean_rs: f64 = (0..8).map(|b| regret_at(fig3, "rs", t, b)).sum::<f64>() / 8.0;
            check(
                &format!("{m} beats RS on average ({})", t.name()),
                mean_m < mean_rs,
                format!("{mean_m:.3} vs rs {mean_rs:.3}"),
            );
        }
    }

    // (c) CB-CherryPick improves on the independent (x3) adaptation. The
    // paper also shows it beating x1; on our simulated substrate x1's
    // flat GP is anomalously strong (smooth response surfaces) — reported
    // informationally, discussed in EXPERIMENTS.md.
    for t in BOTH_TARGETS {
        let cb: f64 = (0..8).map(|b| regret_at(fig3, "cb-cherrypick", t, b)).sum::<f64>() / 8.0;
        let x1: f64 = (0..8).map(|b| regret_at(fig3, "cherrypick-x1", t, b)).sum::<f64>() / 8.0;
        let x3: f64 = (0..8).map(|b| regret_at(fig3, "cherrypick-x3", t, b)).sum::<f64>() / 8.0;
        // Tolerance: one regret-point of seed noise at reduced seed counts.
        check(
            &format!("CB-CherryPick <= CherryPick-x3 ({})", t.name()),
            cb <= x3 + 0.005,
            format!("cb {cb:.3} vs x3 {x3:.3} (x1 {x1:.3}, informational)"),
        );
    }

    // (d) Savings: CB-RBFOpt positive medians; exhaustive strictly negative.
    for target in BOTH_TARGETS {
        let get = |m: &str| {
            dists
                .iter()
                .find(|d| d.method == m && d.target == target)
                .unwrap()
                .box_stats()
        };
        let cb = get("cb-rbfopt");
        let ex = get("exhaustive");
        let smac = get("smac");
        check(
            &format!("cb-rbfopt median savings > 0 ({})", target.name()),
            cb.median > 0.0,
            format!("{:+.1}% (paper: +65% cost / +20% time)", 100.0 * cb.median),
        );
        // Tolerance: ~3pp of median seed noise at reduced seed counts.
        check(
            &format!("cb-rbfopt ~>= smac median savings ({})", target.name()),
            cb.median >= smac.median - 0.03,
            format!("cb {:+.1}% vs smac {:+.1}%", 100.0 * cb.median, 100.0 * smac.median),
        );
        check(
            &format!("exhaustive savings strictly negative ({})", target.name()),
            ex.whisker_hi < 0.0 || ex.q3 < 0.0,
            format!("median {:+.1}%", 100.0 * ex.median),
        );
    }

    println!(
        "\n=== e2e suite done in {:.1}s: {pass} checks passed, {fail} failed ===",
        started.elapsed().as_secs_f64()
    );
    if fail > 0 {
        std::process::exit(1);
    }
}
