//! Provider selection under a tight budget: compare how each optimizer
//! family spends 22 evaluations on one workload, and which provider each
//! one commits to.
//!
//! ```bash
//! cargo run --release --example provider_selection
//! ```

use multicloud::dataset::objective::{EvalLedger, EvalSource, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::Config;
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

/// Measurement-source wrapper recording which provider every evaluation
/// went to (the ledger's history could do this too — the wrapper shows
/// that custom sources compose under the ledger). Sources are `&self` +
/// `Sync` so ledger shards can share them across arm workers; side state
/// therefore needs interior mutability.
struct Recording<'a> {
    inner: LookupObjective<'a>,
    providers: std::sync::Mutex<Vec<usize>>,
}

impl EvalSource for Recording<'_> {
    fn measure(&self, cfg: &Config, pull: u64) -> f64 {
        self.providers.lock().unwrap().push(cfg.provider);
        self.inner.measure(cfg, pull)
    }

    fn deterministic(&self) -> bool {
        self.inner.deterministic()
    }
}

fn main() {
    let ds = OfflineDataset::generate(2022, 5);
    let backend: Box<dyn Backend + Send + Sync> = match ArtifactBackend::load(&artifact_dir(None))
    {
        Ok(b) => Box::new(b),
        Err(_) => Box::new(NativeBackend),
    };

    let workload_id = "spectral_clustering:buzz";
    let w = ds.workload_index(workload_id).unwrap();
    let target = Target::Time;
    let budget = 22;

    let (best_id, best_val) = ds.true_min(w, target);
    let grid = ds.domain.full_grid();
    println!("workload {workload_id}, target {}", target.name());
    println!(
        "true optimum: {} at {:.1}s\n",
        grid[best_id].label(&ds.domain),
        best_val
    );
    println!(
        "{:<16} {:>8}  {:<30}  {}",
        "method", "regret", "chosen (provider)", "evals per provider [aws azure gcp]"
    );

    for method in ["rs", "cherrypick-x1", "cherrypick-x3", "smac", "hyperopt", "rb", "cb-cherrypick", "cb-rbfopt"]
    {
        let opt = by_name(method).unwrap();
        let ctx = SearchContext::new(&ds.domain, target, backend.as_ref());
        let rec = Recording {
            inner: LookupObjective::new(&ds, w, target, MeasureMode::SingleDraw, 11),
            providers: std::sync::Mutex::new(Vec::new()),
        };
        let res = {
            let mut ledger = EvalLedger::new(&rec, budget);
            opt.run(&ctx, &mut ledger, &mut Rng::new(5))
        };
        let mut counts = [0usize; 3];
        for &p in rec.providers.lock().unwrap().iter() {
            counts[p] += 1;
        }
        let chosen_gt = rec.inner.ground_truth(&res.best_config);
        println!(
            "{:<16} {:>7.3}  {:<30}  {:?}",
            method,
            (chosen_gt - best_val) / best_val,
            res.best_config.label(&ds.domain),
            counts
        );
    }
    println!("\n(bandit methods concentrate evaluations on one provider; x3 splits evenly)");
}
