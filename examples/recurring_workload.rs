//! Recurring-workload savings scenario (the paper's §IV-E motivation).
//!
//! A business retrains a recommendation model once per hour. Every N = 64
//! runs it may re-optimize its multi-cloud deployment with search budget
//! B = 33. Is the optimization worth it compared to just picking a random
//! provider and configuration? This example walks one workload through
//! the full §IV-E accounting for several methods.
//!
//! ```bash
//! cargo run --release --example recurring_workload
//! ```

use multicloud::coordinator::savings::{savings_analysis, SavingsConfig};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::metrics;
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};

fn main() {
    let ds = OfflineDataset::generate(2022, 5);
    let backend: Box<dyn Backend + Send + Sync> = match ArtifactBackend::load(&artifact_dir(None))
    {
        Ok(b) => Box::new(b),
        Err(_) => Box::new(NativeBackend),
    };

    let workload_id = "xgboost:santander";
    let w = ds.workload_index(workload_id).unwrap();
    let target = Target::Cost;
    let n_runs = 64;

    println!("scenario: '{workload_id}' retrained hourly; re-optimized every {n_runs} runs\n");

    let r_rand = ds.random_strategy_value(w, target);
    println!("random-strategy expected cost : ${r_rand:.4} per run");
    let (_, best) = ds.true_min(w, target);
    println!("true optimal cost             : ${best:.4} per run\n");

    // Walk the accounting explicitly for one method and seed.
    let spec = multicloud::coordinator::experiment::TrialSpec {
        method: "cb-rbfopt".into(),
        workload: w,
        target,
        budget: 33,
        seed: 3,
        ..Default::default()
    };
    let trial = multicloud::coordinator::experiment::run_trial(&ds, backend.as_ref(), &spec);
    let s = metrics::savings(trial.search_expense, trial.chosen_value, r_rand, n_runs);
    println!("cb-rbfopt, one seed:");
    println!("  C_opt (search, one-time) : ${:.4}", trial.search_expense);
    println!("  R_opt (per production run): ${:.4}", trial.chosen_value);
    println!(
        "  total optimized           : ${:.4}  vs random ${:.4}",
        trial.search_expense + n_runs as f64 * trial.chosen_value,
        n_runs as f64 * r_rand
    );
    println!("  savings S                 : {:+.1}%\n", 100.0 * s);

    // Full multi-seed distribution across all 30 workloads (Figure 4's
    // machinery), restricted to a few methods for speed.
    println!("distribution across all 30 workloads (10 seeds):");
    let cfg = SavingsConfig { seeds: 10, ..Default::default() };
    let methods: Vec<String> =
        ["cb-rbfopt", "smac", "rs", "exhaustive"].iter().map(|s| s.to_string()).collect();
    let dists = savings_analysis(&ds, backend.as_ref(), &methods, target, &cfg);
    for d in &dists {
        let b = d.box_stats();
        println!(
            "  {:<12} median {:+6.1}%   IQR [{:+6.1}%, {:+6.1}%]   worst {:+6.1}%",
            d.method,
            100.0 * b.median,
            100.0 * b.q1,
            100.0 * b.q3,
            100.0 * d.per_workload.iter().cloned().fold(f64::INFINITY, f64::min),
        );
    }
    println!("\n(expected shape: cb-rbfopt best, exhaustive strictly negative — Fig. 4)");
}
