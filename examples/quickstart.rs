//! Quickstart: optimize one recurring workload across three clouds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates the offline benchmark dataset, loads the PJRT artifacts if
//! present (else native surrogates), runs CloudBandit with the paper's
//! default budget, and prints the recommended deployment.

use multicloud::coordinator::experiment::{run_trial, TrialSpec};
use multicloud::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

fn main() {
    // 1. The offline benchmark dataset: 30 workloads x 88 multi-cloud
    //    configurations x 5 repeated measurements (simulated substrate —
    //    DESIGN.md §Substitutions).
    let ds = OfflineDataset::generate(2022, 5);
    println!(
        "dataset: {} workloads x {} configurations",
        ds.workload_count(),
        ds.domain.size()
    );

    // 2. Surrogate backend: AOT-compiled PJRT artifacts when available.
    let backend: Box<dyn Backend + Send + Sync> = match ArtifactBackend::load(&artifact_dir(None))
    {
        Ok(b) => {
            println!("backend: PJRT artifacts (pool of {})", b.pool_size());
            Box::new(b)
        }
        Err(e) => {
            println!("backend: native ({e})");
            Box::new(NativeBackend)
        }
    };

    // 3. Optimize: which cloud + configuration minimizes the cost of
    //    retraining XGBoost on the santander dataset every hour?
    let workload = ds.workload_index("xgboost:santander").unwrap();
    let target = Target::Cost;
    let budget = 33;

    let opt = by_name("cb-rbfopt").unwrap();
    // Pull the three provider arms in parallel — results are identical to
    // sequential, only the wall-clock changes.
    let ctx = SearchContext::new(&ds.domain, target, backend.as_ref())
        .with_arm_workers(ds.domain.provider_count());
    let src = LookupObjective::new(&ds, workload, target, MeasureMode::SingleDraw, 1);
    // The ledger enforces the budget and does all the accounting; the
    // optimizer only decides how to spend it.
    let mut ledger = EvalLedger::new(&src, budget);
    let result = opt.run(&ctx, &mut ledger, &mut Rng::new(7));
    let spend = ledger.total_expense();
    drop(ledger);

    println!("\nCloudBandit (RBFOpt component), budget {budget}:");
    println!("  recommended : {}", result.best_config.label(&ds.domain));
    println!("  est. cost   : ${:.4} per run", src.ground_truth(&result.best_config));
    let (_, best) = ds.true_min(workload, target);
    println!("  true optimum: ${best:.4} per run");
    println!("  search spend: ${spend:.4} (one-time)");

    // 4. The same thing through the coordinator's trial API (what the
    //    figures and the TCP service use).
    let spec = TrialSpec {
        method: "cb-rbfopt".into(),
        workload,
        target,
        budget,
        seed: 7,
        ..TrialSpec::default()
    };
    let trial = run_trial(&ds, backend.as_ref(), &spec);
    println!("\ncoordinator trial: regret {:.4} after {} evaluations", trial.regret, trial.evals);
}
