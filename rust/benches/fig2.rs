//! Bench target for Figure 2 (F2 in DESIGN.md §4): regret of adapted
//! state-of-the-art predictive + search methods vs budget, both targets.
//!
//! Regenerates the figure end-to-end on a reduced seed count (override
//! with BENCH_SEEDS; the paper uses 50 — `multicloud figures --fig2
//! --seeds 50` reproduces it at full scale) and reports wall-clock +
//! trial throughput.

use multicloud::benchkit::Suite;
use multicloud::coordinator::experiment::RegretGrid;
use multicloud::dataset::{OfflineDataset, BOTH_TARGETS};
use multicloud::report::figures;
use multicloud::surrogate::NativeBackend;

const METHODS: [&str; 7] = [
    "predict-linear",
    "predict-rf",
    "rs",
    "cherrypick-x1",
    "cherrypick-x3",
    "bilal-x1",
    "bilal-x3",
];

fn main() {
    let seeds: usize =
        std::env::var("BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let ds = OfflineDataset::generate(2022, 5);
    let backend = NativeBackend;

    let mut grid = RegretGrid::new(&ds, &backend);
    grid.methods = METHODS.iter().map(|m| m.to_string()).collect();
    grid.seeds = seeds;

    let trials = METHODS.len() * 8 * 30 * seeds * 2;
    let t0 = std::time::Instant::now();
    let curves = grid.run();
    let elapsed = t0.elapsed();

    println!("{}", figures::regret_ascii("fig2 (bench-scale)", &curves, &BOTH_TARGETS));
    let mut suite = Suite::new("fig2 — end-to-end regeneration");
    suite.record("fig2 grid (trials)", elapsed.as_nanos() as f64, trials as f64);
    suite.finish();
}
