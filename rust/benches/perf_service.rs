//! Serving-path bench: what the persistent scheduler buys per request.
//!
//! Two measurements:
//!
//! * **requests/sec** through `Service::handle` for deterministic-mode
//!   requests, cold (every request a distinct cache key, full trial) vs.
//!   response-cached (repeat keys answered from the scheduler's
//!   cross-request cache with zero new measurements);
//! * **per-sweep fan-out latency**: the Rising-Bandits-shaped pattern
//!   (many small K-way fan-outs per trial) on the persistent worker team
//!   vs. the old spawn-scoped-threads-per-sweep path
//!   (`parallel_map_owned_spawn`), with a bit-identity check.

use std::sync::Arc;

use multicloud::benchkit::{black_box, Suite};
use multicloud::coordinator::service::Service;
use multicloud::dataset::OfflineDataset;
use multicloud::surrogate::NativeBackend;
use multicloud::util::threadpool::{parallel_map_owned, parallel_map_owned_spawn};

fn main() {
    let ds = Arc::new(OfflineDataset::generate(2022, 3));
    let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend));
    let mut suite = Suite::new("perf_service — request path: cold vs cached, spawn vs team");

    // -- requests/sec: cold vs response-cached ------------------------------
    //
    // Deterministic mode (mean) so responses are cacheable; cold requests
    // rotate the seed so every one is a distinct cache key.
    let req = |seed: usize| {
        format!(
            r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":{seed},"measure_mode":"mean"}}"#
        )
    };
    let mut seed = 0usize;
    let cold = suite.bench("optimize: cold (distinct keys)", || {
        seed += 1;
        black_box(svc.handle(&req(seed)))
    });
    let cold_rps = 1e9 / cold.mean_ns;

    let warm_line = req(1);
    svc.handle(&warm_line); // populate the entry
    let reads_before = ds.measurement_reads();
    let cached = suite.bench("optimize: response-cached", || black_box(svc.handle(&warm_line)));
    let cached_rps = 1e9 / cached.mean_ns;
    assert_eq!(
        ds.measurement_reads(),
        reads_before,
        "cached requests must perform zero new source measurements"
    );
    println!(
        "\nrequests/sec   cold {cold_rps:>10.1}   cached {cached_rps:>12.1}   ({:.0}x)",
        cached_rps / cold_rps.max(1e-12)
    );

    // -- per-sweep fan-out: spawn-per-sweep vs persistent team --------------
    //
    // A Rising-Bandits trial at B=33 fans K=3 single-pull arm tasks once
    // per sweep (~11 sweeps). Replay that shape with a GP-step-sized unit
    // of work per arm and compare the two execution substrates.
    const K: usize = 3;
    const SWEEPS: usize = 11;
    let arm_work = |arm: usize| -> f64 {
        // Roughly one small GP iteration worth of float work.
        let mut acc = arm as f64 + 1.0;
        for i in 0..4_000 {
            acc = (acc * 1.000_000_3 + i as f64 * 1e-9).sqrt() + 0.5;
        }
        acc
    };
    let trial = |fan: &dyn Fn(Vec<usize>) -> Vec<f64>| -> f64 {
        let mut total = 0.0;
        for _ in 0..SWEEPS {
            total += fan((0..K).collect()).iter().sum::<f64>();
        }
        total
    };
    let team_fan = |arms: Vec<usize>| parallel_map_owned(arms, K, arm_work);
    let spawn_fan = |arms: Vec<usize>| parallel_map_owned_spawn(arms, K, arm_work);
    let check_team = trial(&team_fan);
    let check_spawn = trial(&spawn_fan);
    assert_eq!(
        check_team.to_bits(),
        check_spawn.to_bits(),
        "team and spawn substrates must agree bit-for-bit"
    );

    let team = suite
        .bench_units("trial fan-out: persistent team", (SWEEPS * K) as f64, &mut || {
            black_box(trial(&team_fan))
        })
        .mean_ns;
    let spawn = suite
        .bench_units("trial fan-out: spawn per sweep", (SWEEPS * K) as f64, &mut || {
            black_box(trial(&spawn_fan))
        })
        .mean_ns;
    println!(
        "trial fan-out  team {:>8.2} ms   spawn-per-sweep {:>8.2} ms   ({:.2}x)",
        team / 1e6,
        spawn / 1e6,
        spawn / team.max(1e-12)
    );

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_service.csv", suite.to_csv()).ok();
    std::fs::write("results/BENCH_service.json", suite.to_json()).ok();
}
