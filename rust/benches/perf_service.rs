//! Serving-path bench: what the persistent scheduler buys per request.
//!
//! Seven measurements:
//!
//! * **requests/sec** through `Service::handle` for deterministic-mode
//!   requests, cold (every request a distinct cache key, full trial) vs.
//!   response-cached (repeat keys answered from the scheduler's
//!   cross-request cache with zero new measurements), plus the same
//!   sweeps through the shared `FrameScanner` + `Service::serve_frame`
//!   wire path once per codec (`[codec=json]` / `[codec=binary]`);
//! * **per-sweep fan-out latency**: the Rising-Bandits-shaped pattern
//!   (many small K-way fan-outs per trial) on the persistent worker team
//!   vs. the old spawn-scoped-threads-per-sweep path
//!   (`parallel_map_owned_spawn`), with a bit-identity check;
//! * **connection scaling**: TCP round-trip latency of one active
//!   client while an idle keep-alive herd is parked on the server —
//!   epoll swept to 100k sockets (interest is registered once, so each
//!   wakeup costs O(ready events) and the column should stay flat),
//!   the poll fallback to 10k (every wakeup rescans the whole fd set,
//!   so it degrades linearly), and the thread-per-connection fallback
//!   at 0 idle for reference. Herds are clamped to `RLIMIT_NOFILE`
//!   (raised toward the hard limit first — two fds per in-process
//!   connection), and client destinations rotate across `127.0.0.x`
//!   to dodge the ~28k ephemeral-port ceiling per address pair;
//! * **reactor scaling**: aggregate req/s of C concurrent clients
//!   hammering one response-cached key while `--reactors` sweeps
//!   1 → 2 → 4. Cached hits cost no trial work, so the single-reactor
//!   column measures the serial event-loop ceiling and the 4-reactor
//!   column the sharded one — the ≥2x-at-4-reactors claim
//!   `BENCH_service.json` records;
//! * **deadline partials**: latency of an `deadline_ms: 0` request —
//!   the trial exits after its guaranteed first pull, so the column
//!   records how much of a cold trial the early exit saves (and the
//!   scheduler's `pulls_saved` tally confirms the budget went unspent);
//! * **online mode**: a dynamic-market regret-over-time request across
//!   T ticks (`units_per_iter` = ticks, so `throughput_per_s` is market
//!   ticks simulated per second, re-optimization epochs included);
//! * **priority lane under saturation**: `stats` round-trip latency
//!   over a real socket while every normal-lane worker is pinned by a
//!   10k-budget trial — the frame sniff routes control-plane ops to the
//!   team's dedicated priority worker, keeping the column interactive
//!   where the old single-lane queue would park it behind the trials.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use multicloud::benchkit::{black_box, Suite};
use multicloud::coordinator::codec;
use multicloud::coordinator::service::{Service, Transport};
use multicloud::dataset::OfflineDataset;
use multicloud::surrogate::NativeBackend;
use multicloud::util::net;
use multicloud::util::threadpool::{parallel_map_owned, parallel_map_owned_spawn};

fn main() {
    let ds = Arc::new(OfflineDataset::generate(2022, 3));
    let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend));
    let mut suite = Suite::new("perf_service — request path: cold vs cached, spawn vs team");

    // -- requests/sec: cold vs response-cached ------------------------------
    //
    // Deterministic mode (mean) so responses are cacheable; cold requests
    // rotate the seed so every one is a distinct cache key.
    let req = |seed: usize| {
        format!(
            r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":{seed},"measure_mode":"mean"}}"#
        )
    };
    let mut seed = 0usize;
    let cold = suite.bench("optimize: cold (distinct keys)", || {
        seed += 1;
        black_box(svc.handle(&req(seed)))
    });
    let cold_rps = 1e9 / cold.mean_ns;

    let warm_line = req(1);
    svc.handle(&warm_line); // populate the entry
    let reads_before = ds.measurement_reads();
    let cached = suite.bench("optimize: response-cached", || black_box(svc.handle(&warm_line)));
    let cached_rps = 1e9 / cached.mean_ns;
    assert_eq!(
        ds.measurement_reads(),
        reads_before,
        "cached requests must perform zero new source measurements"
    );
    println!(
        "\nrequests/sec   cold {cold_rps:>10.1}   cached {cached_rps:>12.1}   ({:.0}x)",
        cached_rps / cold_rps.max(1e-12)
    );

    // -- per-codec wire path: framing + decode + dispatch + encode ----------
    //
    // The same cold/cached sweeps pushed through the shared FrameScanner
    // and `Service::serve_frame` — everything a request costs above the
    // socket read, per codec. The `Service::handle` labels above are the
    // PR 6 regression guard; these pin the codec seam itself. The binary
    // codec's cached-hit edge (no newline scan, no UTF-8 validation,
    // length-prefixed writes straight from the cache string) is recorded
    // here, not asserted: the JSON payload parse dominates both.
    let mut cached_by_codec: Vec<(&str, f64)> = Vec::new();
    for (codec_name, codec) in [
        ("json", &codec::JSON_LINES as &'static dyn codec::Codec),
        ("binary", &codec::BINARY),
    ] {
        let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend));
        // Pre-negotiated scanner (no magic sniff): exactly a connection
        // that already completed its hello.
        let mut scanner = codec::FrameScanner::new();
        scanner.set_codec(codec);
        let mut wire = Vec::new();
        let serve = |line: &str, scanner: &mut codec::FrameScanner, wire: &mut Vec<u8>| {
            wire.clear();
            codec.encode_frame(line, wire);
            scanner.push(wire);
            let frame = scanner.next_frame().expect("under cap").expect("whole frame");
            svc.serve_frame(&frame, codec)
        };

        let mut seed = 0usize;
        suite.bench(&format!("optimize: cold [codec={codec_name}]"), || {
            seed += 1;
            black_box(serve(&req(seed), &mut scanner, &mut wire))
        });

        let warm_line = req(1);
        assert!(
            !serve(&warm_line, &mut scanner, &mut wire).is_empty(),
            "warm request must produce a response frame"
        );
        let reads_before = ds.measurement_reads();
        let warm = suite.bench(&format!("optimize: response-cached [codec={codec_name}]"), || {
            black_box(serve(&warm_line, &mut scanner, &mut wire))
        });
        assert_eq!(
            ds.measurement_reads(),
            reads_before,
            "[codec={codec_name}] cached requests must perform zero new source measurements"
        );
        cached_by_codec.push((codec_name, 1e9 / warm.mean_ns));
    }
    if let [(_, json_rps), (_, bin_rps)] = cached_by_codec[..] {
        println!(
            "wire cached    json {json_rps:>10.1}   binary {bin_rps:>12.1}   ({:.2}x)",
            bin_rps / json_rps.max(1e-12)
        );
    }

    // -- per-sweep fan-out: spawn-per-sweep vs persistent team --------------
    //
    // A Rising-Bandits trial at B=33 fans K=3 single-pull arm tasks once
    // per sweep (~11 sweeps). Replay that shape with a GP-step-sized unit
    // of work per arm and compare the two execution substrates.
    const K: usize = 3;
    const SWEEPS: usize = 11;
    let arm_work = |arm: usize| -> f64 {
        // Roughly one small GP iteration worth of float work.
        let mut acc = arm as f64 + 1.0;
        for i in 0..4_000 {
            acc = (acc * 1.000_000_3 + i as f64 * 1e-9).sqrt() + 0.5;
        }
        acc
    };
    let trial = |fan: &dyn Fn(Vec<usize>) -> Vec<f64>| -> f64 {
        let mut total = 0.0;
        for _ in 0..SWEEPS {
            total += fan((0..K).collect()).iter().sum::<f64>();
        }
        total
    };
    let team_fan = |arms: Vec<usize>| parallel_map_owned(arms, K, arm_work);
    let spawn_fan = |arms: Vec<usize>| parallel_map_owned_spawn(arms, K, arm_work);
    let check_team = trial(&team_fan);
    let check_spawn = trial(&spawn_fan);
    assert_eq!(
        check_team.to_bits(),
        check_spawn.to_bits(),
        "team and spawn substrates must agree bit-for-bit"
    );

    let team = suite
        .bench_units("trial fan-out: persistent team", (SWEEPS * K) as f64, &mut || {
            black_box(trial(&team_fan))
        })
        .mean_ns;
    let spawn = suite
        .bench_units("trial fan-out: spawn per sweep", (SWEEPS * K) as f64, &mut || {
            black_box(trial(&spawn_fan))
        })
        .mean_ns;
    println!(
        "trial fan-out  team {:>8.2} ms   spawn-per-sweep {:>8.2} ms   ({:.2}x)",
        team / 1e6,
        spawn / 1e6,
        spawn / team.max(1e-12)
    );

    // -- connection scaling: one active client vs an idle herd --------------
    //
    // Round-trip a cached deterministic request (so the measurement is
    // transport, not trial, time) while N idle keep-alive connections
    // are parked on the server. Registered interest is the point:
    // epoll's column should stay flat to 100k parked sockets while the
    // poll fallback, which rescans every fd per wakeup, degrades
    // linearly — that crossover is the curve BENCH_service.json exists
    // to pin.
    //
    // Each in-process connection burns two fds (client + server end),
    // so herds are clamped to the soft RLIMIT_NOFILE after trying to
    // raise it toward the hard limit. Clamped duplicates collapse to a
    // single measurement and the label reports the real herd size.
    #[cfg(unix)]
    let herd_cap: usize = {
        let (soft, hard) = net::raise_nofile_limit(210_000).unwrap_or((4096, 4096));
        let cap = (soft.saturating_sub(128) / 2).min(100_000) as usize;
        println!("\nfd budget: soft {soft}, hard {hard} -> idle-herd cap {cap}");
        cap
    };
    #[cfg(not(unix))]
    let herd_cap: usize = 256;

    let active_req = br#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":1,"measure_mode":"mean"}"#;
    let rtt = |suite: &mut Suite, label: &str, transport: Transport, idle_conns: usize| {
        let svc = Arc::new(
            Service::new(Arc::clone(&ds), Arc::new(NativeBackend))
                .with_conn_workers(4)
                .with_transport(transport)
                .with_max_conns(idle_conns + 32),
        );
        let stop = Arc::new(AtomicBool::new(false));
        // Bind the wildcard address so rotated loopback destinations
        // (127.0.0.2, ...) all land on the same listener.
        let (port, handle) = Arc::clone(&svc).serve("0.0.0.0:0", Arc::clone(&stop)).expect("bind");
        let connect = |i: usize| -> TcpStream {
            // A fresh destination address every 20k connections keeps
            // each (src, dst) pair under the ephemeral-port ceiling.
            let dst = format!("127.0.0.{}", 1 + (i / 20_000) % 254);
            let mut tries = 0;
            loop {
                match TcpStream::connect((dst.as_str(), port)) {
                    Ok(c) => {
                        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                        break c;
                    }
                    Err(_) if tries < 50 => {
                        // Transient accept-backlog overflow while the
                        // loop drains a connect burst: back off briefly.
                        tries += 1;
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => panic!("connect {i} to {dst}:{port}: {e}"),
                }
            }
        };
        let idle: Vec<TcpStream> = (0..idle_conns).map(&connect).collect();
        let mut conn = connect(idle_conns);
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        {
            let mut roundtrip = || {
                conn.write_all(active_req).unwrap();
                conn.write_all(b"\n").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                assert!(line.contains("\"ok\":true"), "{line}");
                line
            };
            roundtrip(); // warm the response cache off the clock
            suite.bench(label, || black_box(roundtrip()));
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        // Close every client socket before joining so the threaded
        // fallback's workers see EOF instead of waiting out a timeout.
        drop(reader);
        drop(conn);
        drop(idle);
        handle.join().unwrap();
    };
    if net::supported() {
        let mut sweeps: Vec<(Transport, usize)> = Vec::new();
        if net::epoll_supported() {
            for n in [0usize, 1_000, 10_000, 100_000] {
                sweeps.push((Transport::Epoll, n.min(herd_cap)));
            }
        }
        // The poll fallback's O(open conns) wakeups make building a
        // 100k herd through it quadratic; 10k suffices to show the
        // linear degradation against epoll's flat column.
        for n in [0usize, 1_000, 10_000] {
            sweeps.push((Transport::Poll, n.min(herd_cap)));
        }
        sweeps.dedup();
        for (transport, idle_conns) in sweeps {
            let label = format!("{} rtt, {idle_conns} idle conns", transport.name());
            rtt(&mut suite, &label, transport, idle_conns);
        }
    }
    rtt(&mut suite, "threaded rtt, 0 idle conns", Transport::Threaded, 0);

    // -- reactor scaling: concurrent cached-hit throughput ------------------
    //
    // C clients hammer one response-cached key over real sockets while
    // the reactor count sweeps 1 -> 2 -> 4. A cached hit costs the
    // server no trial work, so the serving path itself is the
    // bottleneck: with one reactor every wakeup, read, dispatch, and
    // write funnels through a single event-loop thread; sharding the
    // connections across reactors spreads that load. Each iteration is
    // one full concurrent burst (CLIENTS x PER_CLIENT round-trips), so
    // `throughput_per_s` in BENCH_service.json is aggregate requests/s
    // — the column the >= 2x at 4-reactors-vs-1 claim is read from.
    if net::supported() {
        let transport = if net::epoll_supported() { Transport::Epoll } else { Transport::Poll };
        const CLIENTS: usize = 8;
        const PER_CLIENT: usize = 48;
        struct Client {
            conn: TcpStream,
            reader: BufReader<TcpStream>,
        }
        let mut rps_by_reactors: Vec<(usize, f64)> = Vec::new();
        for reactors in [1usize, 2, 4] {
            let svc = Arc::new(
                Service::new(Arc::clone(&ds), Arc::new(NativeBackend))
                    .with_conn_workers(4)
                    .with_transport(transport)
                    .with_reactors(reactors),
            );
            let stop = Arc::new(AtomicBool::new(false));
            let (port, handle) =
                Arc::clone(&svc).serve("127.0.0.1:0", Arc::clone(&stop)).expect("bind");
            let mut clients: Vec<Client> = (0..CLIENTS)
                .map(|i| {
                    let conn = TcpStream::connect(("127.0.0.1", port))
                        .unwrap_or_else(|e| panic!("client {i} connect: {e}"));
                    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                    let reader = BufReader::new(conn.try_clone().unwrap());
                    Client { conn, reader }
                })
                .collect();
            let roundtrip = |c: &mut Client| {
                c.conn.write_all(active_req).unwrap();
                c.conn.write_all(b"\n").unwrap();
                let mut line = String::new();
                c.reader.read_line(&mut line).unwrap();
                assert!(line.contains("\"ok\":true"), "{line}");
                line
            };
            // Warm the response cache — and every connection — off the
            // clock, so the timed bursts measure pure cached serving.
            for c in clients.iter_mut() {
                roundtrip(c);
            }
            let label = format!("concurrent cached rps, reactors={reactors}");
            let res = suite.bench_units(&label, (CLIENTS * PER_CLIENT) as f64, &mut || {
                std::thread::scope(|scope| {
                    for c in clients.iter_mut() {
                        scope.spawn(move || {
                            for _ in 0..PER_CLIENT {
                                black_box(roundtrip(c));
                            }
                        });
                    }
                });
            });
            rps_by_reactors.push((reactors, 1e9 * (CLIENTS * PER_CLIENT) as f64 / res.mean_ns));
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            drop(clients);
            handle.join().unwrap();
        }
        let col = |r: usize| {
            rps_by_reactors.iter().find(|(n, _)| *n == r).map(|(_, v)| *v).unwrap_or(0.0)
        };
        println!(
            "concurrent cached rps   1r {:>10.1}   2r {:>10.1}   4r {:>10.1}   (4r/1r {:.2}x)",
            col(1),
            col(2),
            col(4),
            col(4) / col(1).max(1e-12)
        );
    }

    // -- cancellation: deadline partials ------------------------------------
    //
    // An already-expired deadline exits after the guaranteed first pull;
    // the latency gap to the cold column is the work cancellation saves.
    // Seeds rotate so no iteration could be answered from the cache even
    // if partials were cached (they are not — that's a suite invariant).
    {
        let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend));
        let dl_req = |seed: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":{seed},"measure_mode":"mean","deadline_ms":0}}"#
            )
        };
        let mut seed = 0usize;
        let partial_ns = suite
            .bench("optimize: deadline-cancelled partial", || {
                seed += 1;
                black_box(svc.handle(&dl_req(seed)))
            })
            .mean_ns;
        let sched = svc.scheduler();
        assert!(sched.cancelled_deadline() >= 1, "every iteration must cancel");
        let cold_ns = 1e9 / cold_rps.max(1e-12);
        println!(
            "\ndeadline partial {:>8.1} us   vs cold {:>8.1} us   ({:.1}x less, {} pulls saved)",
            partial_ns / 1e3,
            cold_ns / 1e3,
            cold_ns / partial_ns.max(1e-12),
            sched.pulls_saved(),
        );
    }

    // -- dynamic market: online regret-over-time ----------------------------
    //
    // One online request re-scores (and on schedule re-searches) an
    // incumbent across T market ticks — T regret points per request, so
    // `units_per_iter` is the tick count and `throughput_per_s` reads as
    // market ticks simulated per second. Seeds rotate to keep epochs
    // honest; online responses bypass the response cache by design, and
    // the scheduler counters confirm every iteration ran a real trial.
    {
        let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend));
        const TICKS: usize = 6;
        let online_req = |seed: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":{seed},"measure_mode":"mean","online":{{"ticks":{TICKS},"reoptimize_every":2}}}}"#
            )
        };
        let mut seed = 0usize;
        let online_ns = suite
            .bench_units("optimize: online regret-over-time (6 ticks)", TICKS as f64, &mut || {
                seed += 1;
                black_box(svc.handle(&online_req(seed)))
            })
            .mean_ns;
        let sched = svc.scheduler();
        assert_eq!(sched.cache_hits(), 0, "online requests must bypass the response cache");
        assert!(sched.trials_run() >= 1, "online iterations must run real trials");
        let cold_ns = 1e9 / cold_rps.max(1e-12);
        println!(
            "online mode      {TICKS}-tick trajectory {:>8.1} us   vs one cold trial {:>8.1} us",
            online_ns / 1e3,
            cold_ns / 1e3
        );
    }

    // -- cancellation: priority lane under worker saturation ----------------
    //
    // Both normal-lane workers pinned by slow uncacheable trials (Bilal
    // BO on the time target refits a random forest on every pull — slow
    // with linear memory, so they outlast the bench window harmlessly);
    // the stats round-trip must stay interactive through the priority
    // lane. Disconnecting the busy clients afterwards fires their
    // connection tokens, so teardown cancels the trials instead of
    // waiting out two 10k-pull searches.
    if net::supported() {
        let transport = if net::epoll_supported() { Transport::Epoll } else { Transport::Poll };
        let svc = Arc::new(
            Service::new(Arc::clone(&ds), Arc::new(NativeBackend))
                .with_conn_workers(2)
                .with_transport(transport),
        );
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) =
            Arc::clone(&svc).serve("127.0.0.1:0", Arc::clone(&stop)).expect("bind");
        let connect = || {
            let c = TcpStream::connect(("127.0.0.1", port)).expect("connect");
            c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
            c
        };
        let busy: Vec<TcpStream> = (0..2u64)
            .map(|s| {
                let mut c = connect();
                c.write_all(
                    format!(
                        r#"{{"op":"optimize","workload":"kmeans:buzz","target":"time","method":"bilal-x1","budget":10000,"seed":{s},"trial_workers":1}}"#
                    )
                    .as_bytes(),
                )
                .unwrap();
                c.write_all(b"\n").unwrap();
                c
            })
            .collect();
        std::thread::sleep(Duration::from_millis(200));

        let mut conn = connect();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut stats_rtt = || {
            conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("priority_served"), "{line}");
            line
        };
        stats_rtt(); // warm, off the clock
        let loaded_ns = suite
            .bench("stats rtt, saturated workers (priority lane)", || black_box(stats_rtt()))
            .mean_ns;
        println!(
            "priority lane    stats rtt {:>8.1} us with every normal worker pinned",
            loaded_ns / 1e3
        );
        drop(busy); // EOF fires the trials' tokens: bounded teardown
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        drop(reader);
        drop(conn);
        handle.join().unwrap();
    }

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_service.csv", suite.to_csv()).ok();
    std::fs::write("results/BENCH_service.json", suite.to_json()).ok();
    // Refresh the committed repo-root baseline too (cargo runs benches
    // from the package root, so `..` is the repository root).
    std::fs::write("../BENCH_service.json", suite.to_json()).ok();
}
