//! §Perf L3 bench: simulator + dataset substrate throughput (measurement
//! generation, objective lookups, encoding) — these sit under every
//! trial, so they must be negligible next to surrogate math.

use multicloud::benchkit::{black_box, Suite};
use multicloud::dataset::objective::{EvalSource, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::{encode, Domain};
use multicloud::simulator::tasks::all_workloads;
use multicloud::simulator::{expected_runtime_s, measure};
use multicloud::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("perf_simulator — substrate throughput");
    suite.max_seconds = 1.0;

    let d = Domain::paper();
    let grid = d.full_grid();
    let ws = all_workloads();

    suite.bench_units("expected_runtime_s (full 30x88 sweep)", (30 * 88) as f64, &mut || {
        let mut acc = 0.0;
        for w in &ws {
            for c in &grid {
                acc += expected_runtime_s(&d, w, c);
            }
        }
        black_box(acc)
    });

    let mut rng = Rng::new(1);
    suite.bench_units("measure() with noise (1k draws)", 1000.0, &mut || {
        let mut acc = 0.0;
        for _ in 0..1000 {
            acc += measure(&d, &ws[3], &grid[17], &mut rng).0;
        }
        black_box(acc)
    });

    suite.bench_units("encode() full grid", grid.len() as f64, &mut || {
        grid.iter().map(|c| encode(&d, c)[3]).sum::<f64>()
    });

    let ds = OfflineDataset::generate(2022, 5);
    suite.bench_units("objective measure (SingleDraw, 1k)", 1000.0, &mut || {
        let src = LookupObjective::new(&ds, 7, Target::Cost, MeasureMode::SingleDraw, 5);
        let mut acc = 0.0;
        for i in 0..1000 {
            acc += src.measure(&grid[i % grid.len()], (i / grid.len()) as u64);
        }
        black_box(acc)
    });

    suite.bench("true_min (one workload, both targets)", || {
        (ds.true_min(4, Target::Time), ds.true_min(4, Target::Cost))
    });

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_simulator.csv", suite.to_csv()).ok();
}
