//! §Perf L2/runtime bench: surrogate fit+predict latency, native vs PJRT
//! artifact, across observation counts — the per-iteration hot path of
//! every BO-family optimizer. Times three generations of the GP predict
//! path against each other (full refit, incremental with per-candidate
//! solves, whitened pinned cache), isolates artifact execution vs buffer
//! marshalling, and measures the executable-pool effect.
//!
//! Emits `results/BENCH_gp.json` (machine-readable, via
//! `benchkit::Suite::to_json`) so the perf trajectory of the hot loop is
//! tracked across PRs, alongside the human-readable CSV.

use multicloud::benchkit::{black_box, Suite};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::encode;
use multicloud::linalg::Matrix;
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

fn problem(n: usize) -> (Matrix, Vec<f64>, Matrix) {
    let ds = OfflineDataset::generate(2022, 3);
    let grid = ds.domain.full_grid();
    let mut rng = Rng::new(42);
    let idx = rng.sample_indices(grid.len(), n.min(grid.len()));
    let x = Matrix::from_rows(
        &idx.iter().map(|&i| encode(&ds.domain, &grid[i])).collect::<Vec<Vec<f64>>>(),
    );
    let y: Vec<f64> = idx.iter().map(|&i| ds.mean_value(5, i, Target::Cost)).collect();
    let cands = Matrix::from_rows(
        &grid.iter().map(|c| encode(&ds.domain, c)).collect::<Vec<Vec<f64>>>(),
    );
    (x, y, cands)
}

fn main() {
    let mut suite = Suite::new("perf_gp — surrogate hot path (native vs PJRT artifact)");
    suite.max_seconds = 1.5;

    let native = NativeBackend;
    for n in [8usize, 32, 88] {
        let (x, y, cands) = problem(n);
        suite.bench(&format!("native gp_fit_predict n={n} m=88"), || {
            black_box(native.gp_fit_predict(&x, &y, &cands)).mean[0]
        });
        suite.bench(&format!("native rbf_fit_predict n={n} m=88"), || {
            black_box(native.rbf_fit_predict(&x, &y, 1e-6, &cands)).pred[0]
        });
    }

    // Incremental vs full-refit fits: simulate a BO run growing from 0 to
    // n observations with one predict per step — exactly what every
    // GP-backed optimizer iteration pays. "full refit" rebuilds the
    // Cholesky per step (the pre-EvalLedger behaviour); "incremental"
    // appends a rank-1 border per step but still pays one triangular
    // solve per candidate per predict; "whitened pinned" is the cached
    // V = L⁻¹K(X,C) path the BO loop actually runs — O(n·m) dots per
    // predict, zero per-candidate solves.
    for n in [8usize, 32, 88] {
        let (x, y, cands) = problem(n);
        suite.bench(&format!("gp full-refit run n=0..{n} m=88 (old)"), || {
            let mut acc = 0.0;
            for i in 1..=n {
                let xi = Matrix::from_rows(
                    &(0..i).map(|r| x.row(r).to_vec()).collect::<Vec<Vec<f64>>>(),
                );
                acc += native.gp_fit_predict(&xi, &y[..i], &cands).mean[0];
            }
            black_box(acc)
        });
        suite.bench(&format!("gp incremental run n=0..{n} m=88 (per-cand solves)"), || {
            let mut sess = native.gp_session();
            let mut acc = 0.0;
            for i in 0..n {
                sess.observe(x.row(i).to_vec(), y[i]);
                acc += sess.predict(&cands).mean[0];
            }
            black_box(acc)
        });
        suite.bench(&format!("gp whitened pinned run n=0..{n} m=88 (new)"), || {
            let mut sess = native.gp_session();
            sess.pin_candidates(&cands);
            let mut acc = 0.0;
            for i in 0..n {
                sess.observe(x.row(i).to_vec(), y[i]);
                acc += sess.predict_pinned().mean[0];
            }
            black_box(acc)
        });
    }

    // Steady-state per-prediction cost at fixed n: the marginal predict
    // the tail of a large-budget trial pays on every iteration.
    for n in [32usize, 88] {
        let (x, y, cands) = problem(n);
        let mut unpinned = native.gp_session();
        let mut pinned = native.gp_session();
        pinned.pin_candidates(&cands);
        for i in 0..n {
            unpinned.observe(x.row(i).to_vec(), y[i]);
            pinned.observe(x.row(i).to_vec(), y[i]);
        }
        suite.bench(&format!("gp predict (per-cand solves) n={n} m=88"), || {
            black_box(unpinned.predict(&cands)).mean[0]
        });
        suite.bench(&format!("gp predict_pinned (whitened) n={n} m=88"), || {
            black_box(pinned.predict_pinned()).mean[0]
        });
    }

    match ArtifactBackend::load_with_pool(&artifact_dir(None), 1) {
        Ok(art) => {
            for n in [8usize, 32, 88] {
                let (x, y, cands) = problem(n);
                suite.bench(&format!("artifact gp_fit_predict n={n} m=88 (4 ls execs)"), || {
                    black_box(art.gp_fit_predict(&x, &y, &cands)).mean[0]
                });
                suite.bench(&format!("artifact rbf_fit_predict n={n} m=88"), || {
                    black_box(art.rbf_fit_predict(&x, &y, 1e-6, &cands)).pred[0]
                });
            }
            // Compile cost (pool slot construction).
            suite.bench("artifact load_with_pool(1) [compile both graphs]", || {
                ArtifactBackend::load_with_pool(&artifact_dir(None), 1).unwrap().pool_size()
            });
        }
        Err(e) => eprintln!("(artifact benches skipped: {e} — run `make artifacts`)"),
    }

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_gp.csv", suite.to_csv()).ok();
    std::fs::write("results/BENCH_gp.json", suite.to_json()).ok();
}
