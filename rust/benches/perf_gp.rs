//! §Perf L2/runtime bench: surrogate fit+predict latency, native vs PJRT
//! artifact, across observation counts — the per-iteration hot path of
//! every BO-family optimizer. Also times the incremental-Cholesky GP
//! session against the full refit (the O(n²) vs O(n³)-per-iteration
//! story behind the EvalLedger/IncrementalGp redesign), isolates
//! artifact execution vs buffer marshalling, and measures the
//! executable-pool effect.

use multicloud::benchkit::{black_box, Suite};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::encode;
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

fn problem(n: usize) -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>) {
    let ds = OfflineDataset::generate(2022, 3);
    let grid = ds.domain.full_grid();
    let mut rng = Rng::new(42);
    let idx = rng.sample_indices(grid.len(), n.min(grid.len()));
    let x: Vec<Vec<f64>> = idx.iter().map(|&i| encode(&ds.domain, &grid[i])).collect();
    let y: Vec<f64> = idx.iter().map(|&i| ds.mean_value(5, i, Target::Cost)).collect();
    let cands: Vec<Vec<f64>> = grid.iter().map(|c| encode(&ds.domain, c)).collect();
    (x, y, cands)
}

fn main() {
    let mut suite = Suite::new("perf_gp — surrogate hot path (native vs PJRT artifact)");
    suite.max_seconds = 1.5;

    let native = NativeBackend;
    for n in [8usize, 32, 88] {
        let (x, y, cands) = problem(n);
        suite.bench(&format!("native gp_fit_predict n={n} m=88"), || {
            black_box(native.gp_fit_predict(&x, &y, &cands)).mean[0]
        });
        suite.bench(&format!("native rbf_fit_predict n={n} m=88"), || {
            black_box(native.rbf_fit_predict(&x, &y, 1e-6, &cands)).pred[0]
        });
    }

    // Incremental vs full-refit fits: simulate a BO run growing from 0 to
    // n observations with one predict per step — exactly what every
    // GP-backed optimizer iteration pays. "full refit" rebuilds the
    // Cholesky per step (the pre-EvalLedger behaviour); "incremental"
    // appends a rank-1 border per step.
    for n in [8usize, 32, 88] {
        let (x, y, cands) = problem(n);
        suite.bench(&format!("gp full-refit run n=0..{n} m=88"), || {
            let mut acc = 0.0;
            for i in 1..=n {
                acc += native.gp_fit_predict(&x[..i], &y[..i], &cands).mean[0];
            }
            black_box(acc)
        });
        suite.bench(&format!("gp incremental run n=0..{n} m=88"), || {
            let mut sess = native.gp_session();
            let mut acc = 0.0;
            for i in 0..n {
                sess.observe(x[i].clone(), y[i]);
                acc += sess.predict(&cands).mean[0];
            }
            black_box(acc)
        });
    }

    match ArtifactBackend::load_with_pool(&artifact_dir(None), 1) {
        Ok(art) => {
            for n in [8usize, 32, 88] {
                let (x, y, cands) = problem(n);
                suite.bench(&format!("artifact gp_fit_predict n={n} m=88 (4 ls execs)"), || {
                    black_box(art.gp_fit_predict(&x, &y, &cands)).mean[0]
                });
                suite.bench(&format!("artifact rbf_fit_predict n={n} m=88"), || {
                    black_box(art.rbf_fit_predict(&x, &y, 1e-6, &cands)).pred[0]
                });
            }
            // Compile cost (pool slot construction).
            suite.bench("artifact load_with_pool(1) [compile both graphs]", || {
                ArtifactBackend::load_with_pool(&artifact_dir(None), 1).unwrap().pool_size()
            });
        }
        Err(e) => eprintln!("(artifact benches skipped: {e} — run `make artifacts`)"),
    }

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_gp.csv", suite.to_csv()).ok();
}
