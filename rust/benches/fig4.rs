//! Bench target for Figure 4 (F4 in DESIGN.md §4): production savings
//! box plots at B=33, N=64 — SMAC, CB-RBFOpt, RS, exhaustive, both
//! targets. Regenerates the figure end-to-end (BENCH_SEEDS overrides the
//! reduced default; `multicloud figures --fig4 --seeds 50` is
//! paper-scale) and reports wall-clock.

use multicloud::benchkit::Suite;
use multicloud::coordinator::savings::{savings_analysis, SavingsConfig};
use multicloud::dataset::{OfflineDataset, BOTH_TARGETS};
use multicloud::report::figures;
use multicloud::surrogate::NativeBackend;

fn main() {
    let seeds: usize =
        std::env::var("BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let ds = OfflineDataset::generate(2022, 5);
    let backend = NativeBackend;
    let methods: Vec<String> =
        ["smac", "cb-rbfopt", "rs", "exhaustive"].iter().map(|m| m.to_string()).collect();
    let cfg = SavingsConfig { seeds, ..Default::default() };

    let t0 = std::time::Instant::now();
    let mut all = Vec::new();
    for target in BOTH_TARGETS {
        let dists = savings_analysis(&ds, &backend, &methods, target, &cfg);
        println!("-- Figure 4 (bench-scale), target {} --", target.name());
        println!("{}", figures::savings_ascii(&dists));
        all.extend(dists);
    }
    let elapsed = t0.elapsed();

    let trials = methods.len() * 30 * seeds * 2;
    let mut suite = Suite::new("fig4 — end-to-end savings analysis");
    suite.record("fig4 savings (trials)", elapsed.as_nanos() as f64, trials as f64);
    suite.finish();

    // Headline sanity (soft, printed not asserted — the e2e example
    // asserts): CB-RBFOpt median > 0, exhaustive < 0.
    for d in &all {
        let b = d.box_stats();
        println!("{} ({}): median {:+.1}%", d.method, d.target.name(), 100.0 * b.median);
    }
}
