//! §Perf runtime bench: PJRT artifact execution decomposition — compile
//! time, per-execution latency vs observation count, and end-to-end
//! optimizer runs on the artifact backend vs native. Quantifies the
//! fixed PJRT dispatch overhead that dominates at this problem size
//! (see EXPERIMENTS.md §Perf).

use multicloud::benchkit::{black_box, Suite};
use multicloud::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::encode;
use multicloud::linalg::Matrix;
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

fn main() {
    let mut suite = Suite::new("perf_runtime — PJRT artifact path");
    suite.max_seconds = 2.0;

    let dir = artifact_dir(None);
    let art = match ArtifactBackend::load_with_pool(&dir, 1) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("perf_runtime skipped: {e} (run `make artifacts`)");
            return;
        }
    };

    suite.bench("compile both graphs (pool slot)", || {
        ArtifactBackend::load_with_pool(&dir, 1).unwrap().pool_size()
    });

    let ds = OfflineDataset::generate(2022, 3);
    let grid = ds.domain.full_grid();
    let rows: Vec<Vec<f64>> = grid.iter().map(|c| encode(&ds.domain, c)).collect();
    let cands = Matrix::from_rows(&rows);
    for n in [4usize, 44, 88] {
        let x = Matrix::from_rows(&rows[..n]);
        let y: Vec<f64> = (0..n).map(|i| ds.mean_value(2, i, Target::Cost)).collect();
        suite.bench(&format!("gp artifact full fit_predict n={n} (4 execs)"), || {
            black_box(art.gp_fit_predict(&x, &y, &cands)).mean[0]
        });
    }

    // End-to-end optimizer on artifact vs native backend.
    let native = NativeBackend;
    for (label, backend) in
        [("artifact", &art as &dyn Backend), ("native", &native as &dyn Backend)]
    {
        let mut seed = 0u64;
        suite.bench_units(&format!("cherrypick-x1 B=22 on {label}"), 22.0, &mut || {
            seed += 1;
            let opt = by_name("cherrypick-x1").unwrap();
            let ctx = SearchContext::new(&ds.domain, Target::Cost, backend);
            let src =
                LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, seed);
            let mut ledger = EvalLedger::new(&src, 22);
            opt.run(&ctx, &mut ledger, &mut Rng::new(seed)).best_value
        });
        let mut seed = 0u64;
        suite.bench_units(&format!("cb-rbfopt B=22 on {label}"), 22.0, &mut || {
            seed += 1;
            let opt = by_name("cb-rbfopt").unwrap();
            let ctx = SearchContext::new(&ds.domain, Target::Cost, backend);
            let src =
                LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, seed);
            let mut ledger = EvalLedger::new(&src, 22);
            opt.run(&ctx, &mut ledger, &mut Rng::new(seed)).best_value
        });
    }

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_runtime.csv", suite.to_csv()).ok();
}
