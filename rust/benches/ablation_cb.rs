//! Ablation bench: CloudBandit's two design choices (paper §III-D).
//!
//! * growth factor eta — eta = 1 degenerates to uniform round-robin
//!   (no exponential concentration), the paper uses eta = 2;
//! * component BBO — CherryPick-BO vs RBFOpt-lite.
//!
//! Reports mean regret (30 workloads x BENCH_SEEDS seeds, both targets)
//! at B = 33, plus wall-clock per configuration. Regenerates the evidence
//! behind the paper's claim that exponential budget growth is what lets
//! CB "devote exponentially more budget to more promising providers".

use multicloud::benchkit::Suite;
use multicloud::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target, BOTH_TARGETS};
use multicloud::metrics;
use multicloud::optimizers::cloudbandit::{CloudBandit, Component};
use multicloud::optimizers::{Optimizer, SearchContext};
use multicloud::surrogate::NativeBackend;
use multicloud::util::rng::Rng;

fn main() {
    let seeds: usize =
        std::env::var("BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let ds = OfflineDataset::generate(2022, 5);
    let backend = NativeBackend;
    let budget = 33;

    let mut suite = Suite::new("ablation_cb — CloudBandit design choices (B=33)");
    println!(
        "{:<28} {:>12} {:>12}",
        "variant", "regret(time)", "regret(cost)"
    );

    for component in [Component::CherryPick, Component::RbfOpt] {
        for eta in [1.0, 2.0, 3.0] {
            let opt = CloudBandit::new(component, eta);
            let mut per_target = Vec::new();
            let t0 = std::time::Instant::now();
            for target in BOTH_TARGETS {
                let mut regrets = Vec::new();
                for w in 0..ds.workload_count() {
                    let (_, tmin) = ds.true_min(w, target);
                    for seed in 0..seeds {
                        let ctx = SearchContext { domain: &ds.domain, target, backend: &backend };
                        let mut src = LookupObjective::new(
                            &ds,
                            w,
                            target,
                            MeasureMode::SingleDraw,
                            seed as u64,
                        );
                        let r = {
                            let mut ledger = EvalLedger::new(&mut src, budget);
                            opt.run(&ctx, &mut ledger, &mut Rng::new(seed as u64 ^ 0xCB))
                        };
                        let gt = src.ground_truth(&r.best_config);
                        regrets.push(metrics::regret(gt, tmin));
                    }
                }
                per_target.push(multicloud::util::stats::mean(&regrets));
            }
            let label = format!("{component:?} eta={eta}");
            println!("{:<28} {:>12.4} {:>12.4}", label, per_target[0], per_target[1]);
            suite.record(
                &label,
                t0.elapsed().as_nanos() as f64,
                (2 * seeds * ds.workload_count() * budget) as f64,
            );
        }
    }
    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation_cb.csv", suite.to_csv()).ok();
}
