//! Ablation bench: CloudBandit's two design choices (paper §III-D), plus
//! the parallel-arms execution mode.
//!
//! * growth factor eta — eta = 1 degenerates to uniform round-robin
//!   (no exponential concentration), the paper uses eta = 2;
//! * component BBO — CherryPick-BO vs RBFOpt-lite;
//! * arm workers — sequential (`trial_workers` = 1) vs parallel (one
//!   worker per arm, K = 3): bit-identical results, divergent wall-clock.
//!
//! Reports mean regret (30 workloads x BENCH_SEEDS seeds, both targets)
//! at B = 33, plus wall-clock per configuration. Regenerates the evidence
//! behind the paper's claim that exponential budget growth is what lets
//! CB "devote exponentially more budget to more promising providers",
//! and quantifies the speedup of sharded-ledger parallel arm execution.

use multicloud::benchkit::Suite;
use multicloud::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target, BOTH_TARGETS};
use multicloud::metrics;
use multicloud::optimizers::cloudbandit::{CloudBandit, Component};
use multicloud::optimizers::{Optimizer, SearchContext};
use multicloud::surrogate::NativeBackend;
use multicloud::util::rng::Rng;

fn main() {
    let seeds: usize =
        std::env::var("BENCH_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(6);
    let ds = OfflineDataset::generate(2022, 5);
    let backend = NativeBackend;
    let budget = 33;

    let mut suite = Suite::new("ablation_cb — CloudBandit design choices (B=33)");
    println!(
        "{:<28} {:>12} {:>12}",
        "variant", "regret(time)", "regret(cost)"
    );

    for component in [Component::CherryPick, Component::RbfOpt] {
        for eta in [1.0, 2.0, 3.0] {
            let opt = CloudBandit::new(component, eta);
            let mut per_target = Vec::new();
            let t0 = std::time::Instant::now();
            for target in BOTH_TARGETS {
                let mut regrets = Vec::new();
                for w in 0..ds.workload_count() {
                    let (_, tmin) = ds.true_min(w, target);
                    for seed in 0..seeds {
                        let ctx = SearchContext::new(&ds.domain, target, &backend);
                        let src = LookupObjective::new(
                            &ds,
                            w,
                            target,
                            MeasureMode::SingleDraw,
                            seed as u64,
                        );
                        let r = {
                            let mut ledger = EvalLedger::new(&src, budget);
                            opt.run(&ctx, &mut ledger, &mut Rng::new(seed as u64 ^ 0xCB))
                        };
                        let gt = src.ground_truth(&r.best_config);
                        regrets.push(metrics::regret(gt, tmin));
                    }
                }
                per_target.push(multicloud::util::stats::mean(&regrets));
            }
            let label = format!("{component:?} eta={eta}");
            println!("{:<28} {:>12.4} {:>12.4}", label, per_target[0], per_target[1]);
            suite.record(
                &label,
                t0.elapsed().as_nanos() as f64,
                (2 * seeds * ds.workload_count() * budget) as f64,
            );
        }
    }

    // -- Sequential vs parallel arm execution (K = 3 arms) ------------------
    //
    // Same spec, same results (the parity tests pin bit-identity); the
    // only difference is wall-clock per trial.
    println!(
        "\n{:<28} {:>14} {:>14} {:>9}",
        "arms mode", "ms/trial seq", "ms/trial par", "speedup"
    );
    let k = ds.domain.provider_count();
    let trials = (0..ds.workload_count()).step_by(3).count() * seeds;
    for component in [Component::CherryPick, Component::RbfOpt] {
        let opt = CloudBandit::new(component, 2.0);
        let mut wall = [0.0f64; 2]; // [sequential, parallel]
        let mut check = [0.0f64; 2];
        for (mi, workers) in [1usize, k].into_iter().enumerate() {
            let t0 = std::time::Instant::now();
            for w in (0..ds.workload_count()).step_by(3) {
                for seed in 0..seeds {
                    let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend)
                        .with_arm_workers(workers);
                    let src = LookupObjective::new(
                        &ds,
                        w,
                        Target::Cost,
                        MeasureMode::SingleDraw,
                        seed as u64,
                    );
                    let mut ledger = EvalLedger::new(&src, budget);
                    let r = opt.run(&ctx, &mut ledger, &mut Rng::new(seed as u64 ^ 0xCB));
                    check[mi] += r.best_value;
                }
            }
            wall[mi] = t0.elapsed().as_secs_f64();
            suite.record(
                &format!("{component:?} arms={}", if workers == 1 { "seq" } else { "par" }),
                wall[mi] * 1e9,
                (trials * budget) as f64,
            );
        }
        assert_eq!(
            check[0].to_bits(),
            check[1].to_bits(),
            "parallel arms changed results — determinism contract broken"
        );
        println!(
            "{:<28} {:>14.2} {:>14.2} {:>8.2}x",
            format!("{component:?} K={k}"),
            1e3 * wall[0] / trials as f64,
            1e3 * wall[1] / trials as f64,
            wall[0] / wall[1].max(1e-12),
        );
    }

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation_cb.csv", suite.to_csv()).ok();
}
