//! Bench target for Table II (T2 in DESIGN.md §4): regenerate the
//! task/configuration-space table and the offline dataset behind it,
//! timing dataset materialization and persistence.

use multicloud::benchkit::{black_box, Suite};
use multicloud::dataset::OfflineDataset;
use multicloud::domain::Domain;
use multicloud::report::figures;

fn main() {
    let mut suite = Suite::new("table2 — dataset + configuration space");
    suite.max_seconds = 1.0;

    suite.bench("domain::full_grid (88 configs)", || black_box(Domain::paper().full_grid()));
    suite.bench_units("dataset::generate (30x88x5 measurements)", (30 * 88 * 5) as f64, &mut || {
        black_box(OfflineDataset::generate(2022, 5))
    });
    let ds = OfflineDataset::generate(2022, 5);
    suite.bench("dataset::to_csv", || black_box(ds.to_csv()).len());
    let csv = ds.to_csv();
    suite.bench("dataset::from_csv", || black_box(OfflineDataset::from_csv(&csv).unwrap()).reps);

    println!("{}", figures::table2(&ds.domain));
    println!(
        "dataset: {} workloads x {} configs x {} reps, csv {} bytes",
        ds.workload_count(),
        ds.domain.size(),
        ds.reps,
        csv.len()
    );
    suite.finish();
}
