//! §Perf L3 bench: full-trial latency per optimizer (native backend) at
//! small and large budgets, plus coordinator trial throughput and TCP
//! service round-trip latency.

use multicloud::benchkit::Suite;
use multicloud::coordinator::experiment::{run_trial, TrialSpec};
use multicloud::coordinator::service::Service;
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::optimizers::ALL_OPTIMIZERS;
use multicloud::surrogate::NativeBackend;
use std::sync::Arc;

fn main() {
    let ds = OfflineDataset::generate(2022, 5);
    let backend = NativeBackend;
    let mut suite = Suite::new("perf_optimizers — per-trial latency (native backend)");
    suite.max_seconds = 1.0;

    for budget in [11usize, 88] {
        for name in ALL_OPTIMIZERS {
            if name == "exhaustive" && budget == 11 {
                continue;
            }
            let mut seed = 0u64;
            suite.bench_units(&format!("{name} B={budget}"), budget as f64, &mut || {
                seed += 1;
                let spec = TrialSpec {
                    method: name.to_string(),
                    workload: (seed % 30) as usize,
                    target: Target::Cost,
                    budget,
                    seed,
                    ..TrialSpec::default()
                };
                run_trial(&ds, &backend, &spec).regret
            });
        }
    }

    // Predictive baselines.
    for name in ["predict-linear", "predict-rf"] {
        let mut seed = 0u64;
        suite.bench(&format!("{name}"), || {
            seed += 1;
            let spec = TrialSpec {
                method: name.to_string(),
                workload: (seed % 30) as usize,
                target: Target::Time,
                budget: 0,
                seed,
                ..TrialSpec::default()
            };
            run_trial(&ds, &backend, &spec).regret
        });
    }

    // Service round trip (in-process handle, no TCP; TCP adds the kernel).
    let svc = Service::new(Arc::new(OfflineDataset::generate(2022, 5)), Arc::new(NativeBackend));
    let mut i = 0u64;
    suite.bench("service optimize request (rs, B=11)", || {
        i += 1;
        svc.handle(&format!(
            r#"{{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":11,"seed":{i}}}"#
        ))
        .len()
    });

    suite.finish();
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/perf_optimizers.csv", suite.to_csv()).ok();
}
