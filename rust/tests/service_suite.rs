//! Serving-robustness suite: the TCP service under hostile and
//! heavily-concurrent clients, on **all three** transports (epoll
//! readiness, poll readiness, thread-per-connection fallback).
//!
//! Pinned here:
//! * keep-alive starvation: a herd of idle connections larger than the
//!   worker pool must not delay a fresh client (the event loop's reason
//!   to exist — the thread-pinned design fails exactly this);
//! * protocol robustness: byte-trickled frames, mid-request
//!   disconnects, oversized and garbage frames, over-limit batches,
//!   malformed numeric fields — per-slot errors or clean closes, never
//!   a hung worker (and never a silently-defaulted bogus value);
//! * runtime-tunable limits: a short `--idle-timeout` really reaps, a
//!   small `--max-conns` defers (never drops) the over-cap client;
//! * transcript parity: all transports answer a scripted conversation
//!   byte-identically;
//! * response-cache properties under an N-thread hammer over a key set
//!   larger than the cache cap — with one stripe (exact global LRU) and
//!   with several (the lock-striped default);
//! * multi-reactor sharding: N-client socket hammers and scripted
//!   transcripts byte-identical across threaded, 1-reactor, and
//!   4-reactor servers, and global `--max-conns` accounting conserved
//!   across reactor shards;
//! * cancellation: a mid-trial disconnect stops measurement-budget
//!   consumption within one pull, control-plane ops answer through the
//!   priority lane while every normal worker is pinned by slow trials,
//!   and `deadline_ms` partials are byte-identical across every
//!   transport × codec × reactor cell and never enter the response
//!   cache.
//!
//! CI runs this file under a hang guard (`timeout 300 cargo test --test
//! service_suite`), once per transport × codec × reactor cell via
//! `SERVICE_TRANSPORT=epoll | poll | threaded`,
//! `SERVICE_CODEC=json | binary`, and `SERVICE_REACTORS=1 | 4` — the
//! env vars narrow [`transports`], [`codecs`], and [`reactors`] so a
//! regression in any one cell fails its own matrix leg. Unset, every
//! supported transport, both codecs, and both reactor counts run.
//! Transport-shape tests (starvation, reaping, caps, pipelining) run
//! once, in the json leg, so the binary legs add codec coverage without
//! rerunning transport properties.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use multicloud::coordinator::codec::BINARY_MAGIC;
use multicloud::coordinator::service::{Service, Transport, MAX_BATCH, MAX_FRAME};
use multicloud::dataset::OfflineDataset;
use multicloud::surrogate::NativeBackend;
use multicloud::util::json::parse;

fn service() -> Service {
    let ds = Arc::new(OfflineDataset::generate(60, 3));
    Service::new(ds, Arc::new(NativeBackend))
}

/// Every transport this platform genuinely supports (no silent
/// degradation: each listed one runs as itself).
fn all_transports() -> Vec<Transport> {
    let mut out = Vec::new();
    if multicloud::util::net::epoll_supported() {
        out.push(Transport::Epoll);
    }
    if multicloud::util::net::supported() {
        out.push(Transport::Poll);
    }
    out.push(Transport::Threaded);
    out
}

/// The transports under test: [`all_transports`], narrowed to one by
/// the `SERVICE_TRANSPORT` env var when set (the CI matrix). A value
/// this platform cannot run yields an empty list — the suite then
/// passes trivially rather than testing a silently-degraded stand-in.
fn transports() -> Vec<Transport> {
    let mut out = all_transports();
    if let Ok(only) = std::env::var("SERVICE_TRANSPORT") {
        if !only.is_empty() {
            out.retain(|t| t.name() == only);
        }
    }
    out
}

/// The readiness-driven subset of [`transports`] (tests whose shape
/// needs socket registration — connection caps, idle herds).
fn readiness_transports() -> Vec<Transport> {
    transports().into_iter().filter(|t| *t != Transport::Threaded).collect()
}

/// The wire codecs under test, narrowed to one by the `SERVICE_CODEC`
/// env var when set (the CI matrix).
fn codecs() -> Vec<&'static str> {
    let mut out = vec!["json", "binary"];
    if let Ok(only) = std::env::var("SERVICE_CODEC") {
        if !only.is_empty() {
            out.retain(|c| *c == only);
        }
    }
    out
}

/// The reactor counts under test for the sharded readiness transports:
/// single-reactor (the differential reference) and four-way sharding.
/// Narrowed to one by the `SERVICE_REACTORS` env var when set (the CI
/// matrix's third axis); an unknown value yields an empty list and the
/// reactor-shape tests pass trivially in that leg.
fn reactors() -> Vec<usize> {
    let mut out = vec![1usize, 4];
    if let Ok(only) = std::env::var("SERVICE_REACTORS") {
        if let Ok(n) = only.trim().parse::<usize>() {
            out.retain(|r| *r == n);
        }
    }
    out
}

/// Whether transport-shape tests run in this matrix leg (once, under
/// the json codec — the properties they pin are codec-independent).
fn json_leg() -> bool {
    codecs().contains(&"json")
}

/// Write one binary-codec frame: 4-byte little-endian length + payload.
fn write_binary_frame(conn: &mut TcpStream, payload: &[u8]) {
    conn.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    conn.write_all(payload).unwrap();
    conn.flush().unwrap();
}

/// Read one binary-codec frame, returning its JSON payload as text.
fn read_binary_frame(conn: &mut TcpStream) -> String {
    let mut len = [0u8; 4];
    conn.read_exact(&mut len).expect("read frame length");
    let mut payload = vec![0u8; u32::from_le_bytes(len) as usize];
    conn.read_exact(&mut payload).expect("read frame payload");
    String::from_utf8(payload).expect("response payload is JSON text")
}

/// One request/response round-trip under a named codec: JSON lines or
/// length-prefixed binary, same payloads either way.
fn roundtrip_codec(conn: &mut TcpStream, codec: &str, line: &str) -> String {
    if codec == "binary" {
        write_binary_frame(conn, line.as_bytes());
        read_binary_frame(conn)
    } else {
        roundtrip(conn, line)
    }
}

/// A served instance that stops and joins on drop (so a failing test
/// can't leak a hung acceptor past its own scope).
struct Server {
    svc: Arc<Service>,
    stop: Arc<AtomicBool>,
    port: u16,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    fn start(svc: Service) -> Server {
        let svc = Arc::new(svc);
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) =
            Arc::clone(&svc).serve("127.0.0.1:0", Arc::clone(&stop)).expect("bind");
        Server { svc, stop, port, handle: Some(handle) }
    }

    fn connect(&self) -> TcpStream {
        let conn = TcpStream::connect(("127.0.0.1", self.port)).expect("connect");
        // Every read in this suite is bounded: a hang is a failure, not
        // a stall (and CI adds an outer timeout on the whole file).
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        conn
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One request/response round-trip on an open connection.
fn roundtrip(conn: &mut TcpStream, line: &str) -> String {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    conn.flush().unwrap();
    read_line(conn)
}

fn read_line(conn: &mut TcpStream) -> String {
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).expect("read response");
    line.trim_end().to_string()
}

const OPTIMIZE: &str = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":6,"seed":4,"measure_mode":"mean"}"#;

/// The starvation hammer: with `conn_workers = 2` and 64 idle
/// keep-alive connections parked on the event loop, a fresh client must
/// still get a byte-identical answer within a bounded wait. (The
/// thread-pinned fallback fails this shape by construction: its two
/// workers would be pinned to the first two idle connections forever.)
#[test]
fn keep_alive_starvation_hammer() {
    if !json_leg() {
        return;
    }
    // Reference answer from the thread-per-connection fallback, served
    // with no idle herd in the way.
    let reference = Server::start(service().with_conn_workers(2).with_event_loop(false));
    let expected = roundtrip(&mut reference.connect(), OPTIMIZE);
    assert!(expected.contains("\"ok\":true"), "{expected}");
    drop(reference);

    for transport in readiness_transports() {
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));
        assert_eq!(server.svc.transport(), transport, "explicit choice must stick");
        // 64 idle keep-alive connections — 32x the worker pool.
        let idle: Vec<TcpStream> = (0..64).map(|_| server.connect()).collect();

        let started = Instant::now();
        let mut fresh = server.connect();
        let got = roundtrip(&mut fresh, OPTIMIZE);
        let waited = started.elapsed();
        assert_eq!(
            got,
            expected,
            "{}: answer must be byte-identical to the fallback transport",
            transport.name()
        );
        assert!(waited < Duration::from_secs(30), "bounded wait exceeded: {waited:?}");

        // The idle herd is still serviceable — pick a few parked
        // connections and use them after the fresh client was served.
        for mut conn in idle.into_iter().step_by(21) {
            let pong = roundtrip(&mut conn, r#"{"op":"ping"}"#);
            assert!(pong.contains("pong"), "{pong}");
        }

        // And the loop saw the herd: transport stats flowed through.
        let stats = roundtrip(&mut fresh, r#"{"op":"stats"}"#);
        let v = parse(&stats).unwrap();
        assert_eq!(v.get("event_loop").unwrap().as_bool(), Some(true), "{stats}");
        assert_eq!(v.get("transport").unwrap().as_str(), Some(transport.name()), "{stats}");
        assert!(v.get("loop_wakeups").unwrap().as_usize().unwrap() >= 1, "{stats}");
        assert!(v.get("ready_events").unwrap().as_usize().unwrap() >= 1, "{stats}");
        assert!(v.get("open_connections").unwrap().as_usize().unwrap() >= 1, "{stats}");
    }
}

/// Byte-by-byte trickled frames assemble into exactly one request on
/// every transport.
#[test]
fn partial_frames_trickled_byte_by_byte() {
    if !json_leg() {
        return;
    }
    let reference = service();
    let expected_pong = reference.handle(r#"{"op":"ping"}"#);
    for transport in transports() {
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));
        let mut conn = server.connect();
        for &b in br#"{"op":"ping"}"#.iter() {
            conn.write_all(&[b]).unwrap();
            conn.flush().unwrap();
            if b == b'"' {
                // A few scheduling gaps, not one per byte (test speed).
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        conn.write_all(b"\n").unwrap();
        conn.flush().unwrap();
        assert_eq!(read_line(&mut conn), expected_pong, "{}", transport.name());
    }
}

/// A client that disconnects mid-request (partial frame, no newline)
/// must not hang a worker or poison the scheduler: the next client is
/// served promptly.
#[test]
fn mid_request_disconnect_leaves_the_server_healthy() {
    if !json_leg() {
        return;
    }
    for transport in transports() {
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));
        for _ in 0..4 {
            let mut conn = server.connect();
            conn.write_all(br#"{"op":"optimize","workload":"kme"#).unwrap();
            conn.flush().unwrap();
            drop(conn); // gone mid-frame
        }
        let started = Instant::now();
        let mut conn = server.connect();
        let pong = roundtrip(&mut conn, r#"{"op":"ping"}"#);
        assert!(pong.contains("pong"), "{}: {pong}", transport.name());
        assert!(
            started.elapsed() < Duration::from_secs(10),
            "{}: disconnects delayed the next client",
            transport.name()
        );
    }
}

/// A short idle timeout reaps parked keep-alive connections: the client
/// sees a close (EOF or reset), never a hang, and the server keeps
/// serving fresh arrivals.
#[test]
fn short_idle_timeout_reaps_parked_connections() {
    if !json_leg() {
        return;
    }
    for transport in transports() {
        let name = transport.name();
        let server = Server::start(
            service()
                .with_conn_workers(2)
                .with_transport(transport)
                .with_idle_timeout(Duration::from_millis(300)),
        );
        let mut conn = server.connect();
        assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"), "{name}");

        // Park past the timeout. The blocking read returns only when
        // the server closes the socket (the 60 s client read timeout
        // from `connect` is the hang guard, not the expectation).
        let started = Instant::now();
        let mut byte = [0u8; 1];
        match conn.read(&mut byte) {
            Ok(0) => {} // clean close: reaped
            Ok(_) => panic!("{name}: server sent unsolicited data instead of reaping"),
            Err(e) => {
                use std::io::ErrorKind;
                assert!(
                    matches!(e.kind(), ErrorKind::ConnectionReset | ErrorKind::BrokenPipe),
                    "{name}: expected a close, got {e}"
                );
            }
        }
        assert!(
            started.elapsed() < Duration::from_secs(30),
            "{name}: reap took {:?} for a 300 ms idle timeout",
            started.elapsed()
        );

        // The reap shed a stale socket, not the service.
        let mut fresh = server.connect();
        assert!(roundtrip(&mut fresh, r#"{"op":"ping"}"#).contains("pong"), "{name}");
    }
}

/// At `--max-conns 2`, a third client is deferred in the kernel backlog
/// — not dropped — and gets served the moment a slot frees.
#[test]
fn small_max_conns_defers_but_never_drops_the_over_cap_client() {
    if !json_leg() {
        return;
    }
    for transport in readiness_transports() {
        let name = transport.name();
        let server = Server::start(
            service().with_conn_workers(2).with_transport(transport).with_max_conns(2),
        );
        assert_eq!(server.svc.effective_max_conns(), 2, "{name}");
        let mut a = server.connect();
        let mut b = server.connect();
        assert!(roundtrip(&mut a, r#"{"op":"ping"}"#).contains("pong"), "{name}");
        assert!(roundtrip(&mut b, r#"{"op":"ping"}"#).contains("pong"), "{name}");

        // The cap is visible to clients that did get in.
        let stats = parse(&roundtrip(&mut a, r#"{"op":"stats"}"#)).unwrap();
        assert_eq!(stats.get("max_conns").unwrap().as_usize(), Some(2), "{name}");

        // Third connection: the kernel accepts it into the listen
        // backlog, but the loop must not admit it while at the cap —
        // its request goes unanswered for now...
        let mut c = server.connect();
        c.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        c.flush().unwrap();
        c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        let mut byte = [0u8; 1];
        match c.read(&mut byte) {
            Ok(0) => panic!("{name}: over-cap client was dropped"),
            Ok(_) => panic!("{name}: over-cap client was served past the cap"),
            Err(e) => {
                use std::io::ErrorKind;
                assert!(
                    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut),
                    "{name}: expected deferral, got {e}"
                );
            }
        }

        // ...and is answered as soon as a slot frees: deferred, never
        // dropped.
        drop(a);
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let late = read_line(&mut c);
        assert!(late.contains("pong"), "{name}: deferred client finally gets served: {late}");
    }
}

/// Garbage (non-JSON) frames get per-frame error responses and the
/// connection stays usable; an unterminated oversized frame gets one
/// error and a clean close — and the server keeps serving either way.
#[test]
fn garbage_and_oversized_frames() {
    if !json_leg() {
        return;
    }
    for transport in transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));

        // Garbage JSON: error response, connection still alive.
        let mut conn = server.connect();
        let bad = roundtrip(&mut conn, "!! not json !!");
        assert!(bad.contains("\"ok\":false"), "{name}: {bad}");
        assert!(bad.contains("bad json"), "{name}: {bad}");
        let pong = roundtrip(&mut conn, r#"{"op":"ping"}"#);
        assert!(pong.contains("pong"), "{name}: {pong}");

        // Non-UTF-8 frame: clean close (no response promised), then a
        // fresh connection works.
        let mut conn = server.connect();
        conn.write_all(&[0xff, 0xfe, 0x80, b'\n']).unwrap();
        conn.flush().unwrap();
        let mut sink = Vec::new();
        let _ = conn.try_clone().unwrap().read_to_end(&mut sink); // EOF or reset
        let mut conn = server.connect();
        assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"));

        // Oversized unterminated frame: one error (or a straight close
        // once the cap trips), never a hang, and the server survives.
        let mut conn = server.connect();
        let junk = vec![b'x'; 64 * 1024];
        let mut sent = 0usize;
        while sent <= MAX_FRAME {
            // The server may close mid-stream; write errors then are the
            // expected signal, not a test failure.
            match conn.write_all(&junk) {
                Ok(()) => sent += junk.len(),
                Err(_) => break,
            }
        }
        let mut tail = String::new();
        let outcome = BufReader::new(conn.try_clone().unwrap()).read_line(&mut tail);
        match outcome {
            Ok(0) => {} // clean close before the error line was readable
            Ok(_) => {
                assert!(tail.contains("frame larger than"), "{name}: unexpected response {tail}")
            }
            Err(_) => {} // reset while we were still writing: also a close
        }
        let mut conn = server.connect();
        assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"));

        // A newline-TERMINATED frame just over the cap is rejected the
        // same way on every transport (the cap is about frame size, not
        // about the newline ever arriving).
        let mut conn = server.connect();
        let mut frame = vec![b'y'; MAX_FRAME + 1000];
        frame.push(b'\n');
        for chunk in frame.chunks(64 * 1024) {
            if conn.write_all(chunk).is_err() {
                break; // server closed early: acceptable
            }
        }
        let mut tail = String::new();
        match BufReader::new(conn.try_clone().unwrap()).read_line(&mut tail) {
            Ok(0) | Err(_) => {}
            Ok(_) => assert!(
                tail.contains("frame larger than"),
                "{name}: terminated oversize frame got {tail}"
            ),
        }
        let mut conn = server.connect();
        assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"));
    }
}

/// Request-controlled numeric fields reject malformed values (floats,
/// negatives, non-finite, beyond-exact-integer) with one structured
/// error each — never a silent default, a truncation, or a panic — and
/// the service keeps serving afterwards. Validation is codec- and
/// transport-independent, so this runs once against the dispatcher.
#[test]
fn malformed_numeric_fields_get_structured_errors() {
    if !json_leg() {
        return;
    }
    let svc = service();
    let w = r#""op":"optimize","workload":"kmeans:buzz""#;
    let bad = [
        (format!(r#"{{{w},"budget":-5}}"#), "budget"),
        (format!(r#"{{{w},"budget":2.5}}"#), "budget"),
        (format!(r#"{{{w},"budget":1e300}}"#), "budget"),
        (format!(r#"{{{w},"budget":0}}"#), "budget"),
        (format!(r#"{{{w},"seed":-1}}"#), "seed"),
        (format!(r#"{{{w},"seed":0.5}}"#), "seed"),
        (format!(r#"{{{w},"seed":1e300}}"#), "seed"),
        (format!(r#"{{{w},"deadline_ms":-3}}"#), "deadline_ms"),
        (format!(r#"{{{w},"deadline_ms":0.25}}"#), "deadline_ms"),
        (format!(r#"{{{w},"trial_workers":1.5}}"#), "trial_workers"),
        (format!(r#"{{{w},"trial_workers":-2}}"#), "trial_workers"),
        (format!(r#"{{{w},"online":{{"ticks":0}}}}"#), "online.ticks"),
        (format!(r#"{{{w},"online":{{"ticks":-2}}}}"#), "online.ticks"),
        (format!(r#"{{{w},"online":{{"ticks":1.5}}}}"#), "online.ticks"),
        (format!(r#"{{{w},"online":{{"reoptimize_every":-1}}}}"#), "reoptimize_every"),
        (format!(r#"{{{w},"online":"yes"}}"#), "online"),
        (format!(r#"{{{w},"method":"predict-rf","online":true}}"#), "predictive baseline"),
        (format!(r#"{{{w},"include_pareto":true}}"#), "include_pareto"),
    ];
    for (req, expect) in &bad {
        let resp = svc.handle(req);
        assert!(resp.contains("\"ok\":false"), "{req} got {resp}");
        assert!(resp.contains("\"error\""), "{req} got {resp}");
        assert!(resp.contains(expect), "{req}: error must name the field, got {resp}");
    }
    // Valid boundary values still pass, and the volley left the
    // dispatcher healthy.
    let ok = svc.handle(&format!(r#"{{{w},"method":"rs","budget":2,"seed":0,"deadline_ms":0}}"#));
    assert!(ok.contains("\"ok\":true"), "{ok}");
}

/// Over-limit batches error per request; pipelined requests come back
/// in order, byte-identical to individually issued ones.
#[test]
fn batch_limits_and_pipelining() {
    if !json_leg() {
        return;
    }
    let reference = service();
    let lines = [
        r#"{"op":"ping"}"#.to_string(),
        OPTIMIZE.to_string(),
        r#"{"op":"optimize","workload":"nope"}"#.to_string(),
        r#"{"op":"list_methods"}"#.to_string(),
    ];
    let expected: Vec<String> = lines.iter().map(|l| reference.handle(l)).collect();

    for transport in transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));

        // A batch one past the limit is rejected whole.
        let entries: Vec<String> =
            (0..=MAX_BATCH).map(|_| r#"{"op":"ping"}"#.to_string()).collect();
        let too_big = format!(r#"{{"op":"batch","requests":[{}]}}"#, entries.join(","));
        let mut conn = server.connect();
        let resp = roundtrip(&mut conn, &too_big);
        assert!(resp.contains("\"ok\":false"), "{name}: {resp}");
        assert!(resp.contains("batch larger than"), "{name}: {resp}");

        // Pipelining: all requests written in one burst (plus blank
        // lines, which are skipped), responses strictly in order.
        let mut conn = server.connect();
        let burst = format!("\n{}\n\n{}\n{}\n{}\n", lines[0], lines[1], lines[2], lines[3]);
        conn.write_all(burst.as_bytes()).unwrap();
        conn.flush().unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        for (i, want) in expected.iter().enumerate() {
            let mut got = String::new();
            reader.read_line(&mut got).unwrap();
            assert_eq!(got.trim_end(), want, "{name}: pipelined response {i} out of order");
        }
    }
}

/// Every transport answers one scripted conversation with identical
/// bytes (the differential test the fallback is kept around for).
/// Deliberately ignores the `SERVICE_TRANSPORT` narrowing: parity is a
/// cross-transport property, so all supported backends always run.
#[test]
fn all_transports_produce_byte_identical_transcripts() {
    if !json_leg() {
        return;
    }
    let script = [
        r#"{"op":"ping"}"#.to_string(),
        r#"{"op":"list_workloads"}"#.to_string(),
        OPTIMIZE.to_string(),
        OPTIMIZE.to_string(), // repeat: served from the response cache
        format!(
            r#"{{"op":"batch","requests":[{OPTIMIZE},{{"op":"optimize","workload":"kmeans:buzz","method":"warp-drive"}}]}}"#
        ),
        r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":5,"seed":9,"include_trace":true}"#.to_string(),
        r#"{"op":"clear_cache"}"#.to_string(),
    ];
    let transcript = |transport: Transport| -> Vec<String> {
        let server = Server::start(service().with_conn_workers(3).with_transport(transport));
        let mut conn = server.connect();
        script.iter().map(|line| roundtrip(&mut conn, line)).collect()
    };
    let all = all_transports();
    assert!(all.len() >= 2, "at least two transports exist on any Unix platform");
    let baseline = transcript(all[0]);
    for &t in &all[1..] {
        assert_eq!(
            transcript(t),
            baseline,
            "{} vs {}: transports must produce byte-identical transcripts",
            t.name(),
            all[0].name()
        );
    }
}

/// N client threads hammer one service over a key set larger than the
/// cache cap: every response byte-identical to a serial replay, and the
/// LRU stats hold their invariants (hits + misses = deterministic
/// requests, inserts ≤ misses, evictions ≤ inserts, size ≤ cap). The
/// hammer runs on the default striping (the counters asserted here are
/// sums over per-stripe atomics); the deterministic recency tail pins
/// itself to one stripe, where the cache is an exact global LRU.
#[test]
fn concurrent_response_cache_properties() {
    if !json_leg() {
        return;
    }
    const THREADS: usize = 8;
    const KEYS: usize = 8;
    const ROUNDS: usize = 3;
    const CAP: usize = 4;
    let req = |seed: usize| {
        format!(
            r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":5,"seed":{seed},"measure_mode":"mean"}}"#
        )
    };
    // Serial replay on a fresh service is the byte-level reference.
    let reference = service();
    let expected: Vec<String> = (0..KEYS).map(|k| reference.handle(&req(k))).collect();

    let svc = Arc::new(service().with_cache_cap(CAP));
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let svc = Arc::clone(&svc);
                let expected = &expected;
                scope.spawn(move || {
                    for round in 0..ROUNDS {
                        for i in 0..KEYS {
                            // Rotate per thread and round so threads
                            // collide on different keys at once.
                            let k = (i + t + round) % KEYS;
                            let got = svc.handle(&req(k));
                            assert_eq!(
                                got, expected[k],
                                "thread {t} round {round} key {k} diverged from serial replay"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let s = svc.scheduler();
    let requests = (THREADS * KEYS * ROUNDS) as u64;
    assert_eq!(
        s.cache_hits() + s.cache_misses(),
        requests,
        "every deterministic request is a hit or a miss"
    );
    assert_eq!(s.cache_misses(), s.trials_run(), "each miss runs exactly one trial");
    assert!(s.cache_inserts() <= s.cache_misses(), "inserts cannot exceed misses");
    assert!(s.cache_evictions() <= s.cache_inserts(), "evictions cannot exceed inserts");
    assert!(s.cached_responses() <= CAP, "cache grew past its cap");
    // The cap forced real churn in this workload shape.
    assert!(s.cache_evictions() > 0, "expected evictions with {KEYS} keys over cap {CAP}");

    // Recency refresh on hit, deterministically (single-threaded tail):
    // touch a key, insert new keys up to the cap, and the touched key
    // must still be cached while the untouched one was evicted. One
    // stripe, so eviction order is the exact global LRU order.
    let svc = service().with_cache_cap(2).with_cache_shards(1);
    svc.handle(&req(0)); // cache: [0]
    svc.handle(&req(1)); // cache: [0, 1]
    svc.handle(&req(0)); // refresh 0 -> victim order is [1, 0]
    svc.handle(&req(2)); // evicts 1 -> cache: [0, 2]
    let trials = svc.scheduler().trials_run();
    svc.handle(&req(0));
    assert_eq!(svc.scheduler().trials_run(), trials, "refreshed key must still be cached");
    svc.handle(&req(1));
    assert_eq!(svc.scheduler().trials_run(), trials + 1, "unrefreshed key must have been evicted");
}

/// Codec negotiation on every transport: an explicit hello (ack framed
/// in the pre-switch codec), a bare hello (acks the codec in effect),
/// and the magic-byte open all land on a working connection whose
/// payloads match `Service::handle` exactly.
#[test]
fn codec_negotiation_hello_and_magic() {
    let reference = service();
    let expected_pong = reference.handle(r#"{"op":"ping"}"#);
    let expected_opt = reference.handle(OPTIMIZE);
    for transport in transports() {
        let name = transport.name();
        for codec in codecs() {
            let server = Server::start(service().with_conn_workers(2).with_transport(transport));

            // Explicit hello: ack arrives framed in the codec in effect
            // *before* the switch (JSON lines), then traffic switches.
            let mut conn = server.connect();
            let hello = format!(r#"{{"op":"hello","codec":"{codec}"}}"#);
            let ack = roundtrip(&mut conn, &hello);
            assert_eq!(
                ack,
                format!(r#"{{"ok":true,"codec":"{codec}"}}"#),
                "{name}/{codec}: bad hello ack"
            );
            assert_eq!(roundtrip_codec(&mut conn, codec, r#"{"op":"ping"}"#), expected_pong);
            assert_eq!(roundtrip_codec(&mut conn, codec, OPTIMIZE), expected_opt);

            // The per-codec stats counters saw this connection.
            let stats = roundtrip_codec(&mut conn, codec, r#"{"op":"stats"}"#);
            let v = parse(&stats).unwrap();
            let conns_field = format!("{codec}_connections");
            let reqs_field = format!("{codec}_requests");
            assert!(
                v.get(&conns_field).unwrap().as_usize().unwrap() >= 1,
                "{name}/{codec}: {stats}"
            );
            assert!(
                v.get(&reqs_field).unwrap().as_usize().unwrap() >= 3,
                "{name}/{codec}: {stats}"
            );
            // Free the threaded transport's worker before the next
            // scenario opens its own connection.
            drop(conn);

            if codec == "binary" {
                // Magic-byte open: no hello at all.
                {
                    let mut conn = server.connect();
                    conn.write_all(&[BINARY_MAGIC]).unwrap();
                    write_binary_frame(&mut conn, br#"{"op":"ping"}"#);
                    assert_eq!(read_binary_frame(&mut conn), expected_pong, "{name}: magic open");
                }
                // A pipelined hello + binary burst in one write: the
                // codec must switch before the buffered bytes are
                // scanned.
                let mut conn = server.connect();
                let mut burst = Vec::new();
                burst.extend_from_slice(b"{\"op\":\"hello\",\"codec\":\"binary\"}\n");
                let ping = br#"{"op":"ping"}"#;
                burst.extend_from_slice(&(ping.len() as u32).to_le_bytes());
                burst.extend_from_slice(ping);
                conn.write_all(&burst).unwrap();
                conn.flush().unwrap();
                // Read the ack byte-wise: a BufReader here could slurp
                // the pipelined binary response into its buffer and
                // drop it.
                let mut ack = Vec::new();
                loop {
                    let mut b = [0u8; 1];
                    conn.read_exact(&mut b).unwrap();
                    if b[0] == b'\n' {
                        break;
                    }
                    ack.push(b[0]);
                }
                assert_eq!(ack, br#"{"ok":true,"codec":"binary"}"#, "{name}");
                assert_eq!(read_binary_frame(&mut conn), expected_pong, "{name}: burst");
            } else {
                // A bare hello acks the default codec and changes
                // nothing.
                let mut conn = server.connect();
                let ack = roundtrip(&mut conn, r#"{"op":"hello"}"#);
                assert_eq!(ack, r#"{"ok":true,"codec":"json"}"#, "{name}");
                assert_eq!(roundtrip(&mut conn, r#"{"op":"ping"}"#), expected_pong, "{name}");
            }
        }
    }
}

/// Negotiation robustness: an unknown codec name gets exactly one JSON
/// error and a close; garbage or truncated hellos never wedge the
/// server; a hello after the first frame is an in-band error.
#[test]
fn codec_negotiation_rejects_and_survives_hostile_hellos() {
    if !json_leg() {
        return;
    }
    for transport in transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));

        // Each scenario scopes its connection so the threaded
        // transport's two workers are never pinned by finished clients.

        // Unknown codec: one JSON error naming the choices, then close.
        {
            let mut conn = server.connect();
            let err = roundtrip(&mut conn, r#"{"op":"hello","codec":"msgpack"}"#);
            assert!(err.contains("unknown codec 'msgpack'"), "{name}: {err}");
            assert!(err.contains("json") && err.contains("binary"), "{name}: {err}");
            let mut byte = [0u8; 1];
            match conn.read(&mut byte) {
                Ok(0) => {}
                Ok(_) => panic!("{name}: data after a codec reject"),
                Err(e) => {
                    use std::io::ErrorKind;
                    assert!(
                        matches!(e.kind(), ErrorKind::ConnectionReset | ErrorKind::BrokenPipe),
                        "{name}: expected a close after reject, got {e}"
                    );
                }
            }
        }

        // Garbage mentioning hello is a (malformed) request, not a
        // negotiation; the connection stays usable.
        {
            let mut conn = server.connect();
            let bad = roundtrip(&mut conn, "!! hello garbage !!");
            assert!(bad.contains("bad json"), "{name}: {bad}");
            assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"), "{name}");
        }

        // A truncated hello followed by a disconnect must not wedge
        // anything.
        {
            let mut conn = server.connect();
            conn.write_all(br#"{"op":"hello","codec":"bin"#).unwrap();
            conn.flush().unwrap();
        }
        {
            let mut conn = server.connect();
            assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"), "{name}");
        }

        // A hello that is not the connection's first frame is answered
        // in-band with an error, not renegotiated.
        {
            let mut conn = server.connect();
            assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"), "{name}");
            let late = roundtrip(&mut conn, r#"{"op":"hello","codec":"binary"}"#);
            assert!(late.contains("first frame"), "{name}: {late}");
            assert!(roundtrip(&mut conn, r#"{"op":"ping"}"#).contains("pong"), "{name}");
        }
    }
}

/// Binary-framing robustness: the frame cap trips on the declared
/// length alone (one error, close), and disconnects inside a length
/// prefix or payload leave the server healthy.
#[test]
fn binary_frame_cap_and_partial_prefix_disconnects() {
    if !codecs().contains(&"binary") {
        return;
    }
    for transport in transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));

        // Oversized declared length: rejected before any payload
        // arrives — only the magic, the prefix, and a few bytes are
        // ever sent.
        let mut conn = server.connect();
        conn.write_all(&[BINARY_MAGIC]).unwrap();
        conn.write_all(&((MAX_FRAME as u32) + 1).to_le_bytes()).unwrap();
        conn.write_all(b"tiny").unwrap();
        conn.flush().unwrap();
        let err = read_binary_frame(&mut conn);
        assert!(err.contains("frame larger than"), "{name}: {err}");
        let mut sink = Vec::new();
        let _ = conn.read_to_end(&mut sink);
        assert!(sink.is_empty(), "{name}: data after the oversize error");

        // Disconnect mid-length-prefix and mid-payload: no worker ever
        // saw a frame, the server serves the next client promptly.
        let partials: [&[u8]; 2] =
            [&[BINARY_MAGIC, 0x09], &[BINARY_MAGIC, 0x09, 0x00, 0x00, 0x00, b'{']];
        for partial in partials {
            let mut conn = server.connect();
            conn.write_all(partial).unwrap();
            conn.flush().unwrap();
            drop(conn);
        }
        let started = Instant::now();
        let mut conn = server.connect();
        conn.write_all(&[BINARY_MAGIC]).unwrap();
        write_binary_frame(&mut conn, br#"{"op":"ping"}"#);
        assert!(read_binary_frame(&mut conn).contains("pong"), "{name}");
        assert!(
            started.elapsed() < Duration::from_secs(10),
            "{name}: partial-prefix disconnects delayed the next client"
        );
    }
}

/// Mixed-codec concurrency: JSON-lines and binary clients hammering one
/// server concurrently each receive payloads identical to a serial
/// JSON-lines replay on a fresh service. Runs in every leg (the
/// property is cross-codec by construction).
#[test]
fn mixed_codec_concurrent_clients_match_serial_replay() {
    let script: Vec<String> = vec![
        r#"{"op":"ping"}"#.to_string(),
        OPTIMIZE.to_string(),
        r#"{"op":"optimize","workload":"nope"}"#.to_string(),
        r#"{"op":"list_methods"}"#.to_string(),
        OPTIMIZE.to_string(), // cached repeat under contention
    ];
    let reference = service();
    let expected: Vec<String> = script.iter().map(|l| reference.handle(l)).collect();

    for transport in transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(3).with_transport(transport));
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..6)
                .map(|t| {
                    let server = &server;
                    let script = &script;
                    let expected = &expected;
                    scope.spawn(move || {
                        let codec = if t % 2 == 0 { "json" } else { "binary" };
                        let mut conn = server.connect();
                        if codec == "binary" {
                            conn.write_all(&[BINARY_MAGIC]).unwrap();
                        }
                        for i in 0..script.len() {
                            let j = (i + t) % script.len();
                            let got = roundtrip_codec(&mut conn, codec, &script[j]);
                            assert_eq!(
                                got, expected[j],
                                "{name}: client {t} ({codec}) request {j} diverged"
                            );
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });

        // Both codec populations are visible in the stats.
        let mut conn = server.connect();
        let v = parse(&roundtrip(&mut conn, r#"{"op":"stats"}"#)).unwrap();
        assert!(v.get("json_connections").unwrap().as_usize().unwrap() >= 3, "{name}");
        assert!(v.get("binary_connections").unwrap().as_usize().unwrap() >= 3, "{name}");
    }
}

/// The lock-striped cache under an N-thread hammer with an explicit
/// multi-stripe config: the summed per-stripe counters hold the global
/// invariants (hits + misses = deterministic requests, inserts ≤
/// misses, evictions ≤ inserts), and the global cap is respected even
/// though each stripe evicts on its own.
#[test]
fn striped_cache_hammer_holds_global_invariants() {
    if !json_leg() {
        return;
    }
    const THREADS: usize = 8;
    const KEYS: usize = 10;
    const ROUNDS: usize = 3;
    const CAP: usize = 4;
    const SHARDS: usize = 3;
    let req = |seed: usize| {
        format!(
            r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":5,"seed":{seed},"measure_mode":"mean"}}"#
        )
    };
    let reference = service();
    let expected: Vec<String> = (0..KEYS).map(|k| reference.handle(&req(k))).collect();

    let svc = Arc::new(service().with_cache_cap(CAP).with_cache_shards(SHARDS));
    assert_eq!(svc.scheduler().cache_shards(), SHARDS, "cap {CAP} admits all {SHARDS} stripes");
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let svc = Arc::clone(&svc);
                let expected = &expected;
                let req = &req;
                scope.spawn(move || {
                    for round in 0..ROUNDS {
                        for i in 0..KEYS {
                            let k = (i + t + round) % KEYS;
                            let got = svc.handle(&req(k));
                            assert_eq!(
                                got, expected[k],
                                "thread {t} round {round} key {k} diverged from serial replay"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    let s = svc.scheduler();
    let requests = (THREADS * KEYS * ROUNDS) as u64;
    assert_eq!(
        s.cache_hits() + s.cache_misses(),
        requests,
        "per-stripe hit/miss counters must sum to the request count"
    );
    assert_eq!(s.cache_misses(), s.trials_run(), "each miss runs exactly one trial");
    assert!(s.cache_inserts() <= s.cache_misses(), "inserts cannot exceed misses");
    assert!(s.cache_evictions() <= s.cache_inserts(), "evictions cannot exceed inserts");
    assert!(s.cached_responses() <= CAP, "residency must respect the global cap across stripes");
    // Every distinct key is inserted at least once into stripes whose
    // caps sum to CAP, so at least KEYS - CAP evictions are forced.
    assert!(
        s.cache_evictions() >= (KEYS - CAP) as u64,
        "expected churn: {} evictions for {KEYS} keys over global cap {CAP}",
        s.cache_evictions()
    );
    // The stripe count is operator-visible.
    let v = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
    assert_eq!(v.get("cache_shards").unwrap().as_usize(), Some(SHARDS));
    assert_eq!(v.get("cache_cap").unwrap().as_usize(), Some(CAP));
}

/// N socket clients hammer a sharded server at every reactor count
/// under test: every response byte-identical to a serial replay on a
/// fresh service, and the stats op reports the reactor topology that
/// served the hammer.
#[test]
fn multi_reactor_hammer_matches_serial_replay() {
    if !json_leg() {
        return;
    }
    let script: Vec<String> = vec![
        r#"{"op":"ping"}"#.to_string(),
        OPTIMIZE.to_string(),
        r#"{"op":"optimize","workload":"nope"}"#.to_string(),
        r#"{"op":"list_methods"}"#.to_string(),
        OPTIMIZE.to_string(), // cached repeat under contention
    ];
    let reference = service();
    let expected: Vec<String> = script.iter().map(|l| reference.handle(l)).collect();

    for transport in readiness_transports() {
        for r in reactors() {
            let name = format!("{}/reactors={r}", transport.name());
            let server = Server::start(
                service().with_conn_workers(3).with_transport(transport).with_reactors(r),
            );
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..8)
                    .map(|t| {
                        let server = &server;
                        let script = &script;
                        let expected = &expected;
                        let name = &name;
                        scope.spawn(move || {
                            let mut conn = server.connect();
                            for round in 0..2 {
                                for i in 0..script.len() {
                                    let j = (i + t + round) % script.len();
                                    let got = roundtrip(&mut conn, &script[j]);
                                    assert_eq!(
                                        got, expected[j],
                                        "{name}: client {t} round {round} request {j} diverged"
                                    );
                                }
                            }
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
            });

            // The topology the hammer ran on is visible in the stats.
            let mut conn = server.connect();
            let v = parse(&roundtrip(&mut conn, r#"{"op":"stats"}"#)).unwrap();
            assert_eq!(v.get("reactors").unwrap().as_usize(), Some(r), "{name}");
            let per_open = v.get("per_reactor_open").unwrap().as_arr().unwrap();
            assert_eq!(per_open.len(), r, "{name}: one open-gauge per reactor");
            let per_wake = v.get("per_reactor_wakeups").unwrap().as_arr().unwrap();
            assert_eq!(per_wake.len(), r, "{name}: one wakeup counter per reactor");
        }
    }
}

/// Threaded, 1-reactor, and 4-reactor servers answer one scripted
/// conversation (cold, cached, batch, trace, clear) with identical
/// bytes — the sharded loop's differential references. Deliberately
/// ignores the `SERVICE_REACTORS` and `SERVICE_TRANSPORT` narrowing:
/// parity is a cross-topology property, so all supported shapes always
/// run.
#[test]
fn reactor_counts_produce_byte_identical_transcripts() {
    if !json_leg() {
        return;
    }
    let script = [
        r#"{"op":"ping"}"#.to_string(),
        OPTIMIZE.to_string(),
        OPTIMIZE.to_string(), // repeat: served from the response cache
        format!(
            r#"{{"op":"batch","requests":[{OPTIMIZE},{{"op":"optimize","workload":"kmeans:buzz","method":"warp-drive"}}]}}"#
        ),
        r#"{"op":"optimize","workload":"kmeans:buzz","method":"rs","budget":5,"seed":9,"include_trace":true}"#.to_string(),
        r#"{"op":"clear_cache"}"#.to_string(),
        OPTIMIZE.to_string(), // cold again after the clear
    ];
    let transcript = |svc: Service| -> Vec<String> {
        let server = Server::start(svc.with_conn_workers(3));
        let mut conn = server.connect();
        script.iter().map(|line| roundtrip(&mut conn, line)).collect()
    };
    let baseline = transcript(service().with_event_loop(false));
    for transport in all_transports().into_iter().filter(|t| *t != Transport::Threaded) {
        for r in [1usize, 4] {
            assert_eq!(
                transcript(service().with_transport(transport).with_reactors(r)),
                baseline,
                "{}/reactors={r}: sharded transcript must match the threaded fallback",
                transport.name()
            );
        }
    }
}

/// Global `--max-conns` accounting is conserved across reactor shards:
/// with 4 reactors and a cap of 2, the per-reactor open gauges sum to
/// the global count, the cap still defers (never drops) the over-cap
/// client, and a freed slot is reusable no matter which reactor owned
/// it.
#[test]
fn connection_slots_are_conserved_across_reactor_shards() {
    if !json_leg() {
        return;
    }
    for transport in readiness_transports() {
        let name = transport.name();
        let server = Server::start(
            service()
                .with_conn_workers(2)
                .with_transport(transport)
                .with_max_conns(2)
                .with_reactors(4),
        );
        let mut a = server.connect();
        let mut b = server.connect();
        assert!(roundtrip(&mut a, r#"{"op":"ping"}"#).contains("pong"), "{name}");
        assert!(roundtrip(&mut b, r#"{"op":"ping"}"#).contains("pong"), "{name}");

        // Conservation: the shard gauges sum to the global gauge, which
        // sits exactly at the cap while both clients hold their slots.
        let v = parse(&roundtrip(&mut a, r#"{"op":"stats"}"#)).unwrap();
        let open = v.get("open_connections").unwrap().as_usize().unwrap();
        assert_eq!(open, 2, "{name}: both clients hold slots");
        assert_eq!(v.get("reactors").unwrap().as_usize(), Some(4), "{name}");
        let per_open = v.get("per_reactor_open").unwrap().as_arr().unwrap();
        assert_eq!(per_open.len(), 4, "{name}");
        let sum: usize = per_open.iter().map(|g| g.as_usize().unwrap()).sum();
        assert_eq!(sum, open, "{name}: shard gauges must sum to the global count");

        // The cap itself stays global: a third client is deferred...
        let mut c = server.connect();
        c.write_all(b"{\"op\":\"ping\"}\n").unwrap();
        c.flush().unwrap();
        c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        let mut byte = [0u8; 1];
        match c.read(&mut byte) {
            Ok(0) => panic!("{name}: over-cap client was dropped"),
            Ok(_) => panic!("{name}: over-cap client was served past the cap"),
            Err(e) => {
                use std::io::ErrorKind;
                assert!(
                    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut),
                    "{name}: expected deferral, got {e}"
                );
            }
        }

        // ...and served the moment a slot frees, whichever reactor
        // owned the freed connection.
        drop(b);
        c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let late = read_line(&mut c);
        assert!(late.contains("pong"), "{name}: deferred client finally served: {late}");
    }
}

/// A request slow enough to still be running when the test acts:
/// Bilal-flavoured BO on the `time` target refits a 30-tree random
/// forest from scratch on every pull (linear memory, unlike the GP's
/// quadratic factor cache), so a 10k-budget trial takes far longer
/// than the test's sleeps — without cancellation it would burn the
/// whole budget.
fn slow_optimize(seed: u64) -> String {
    format!(
        r#"{{"op":"optimize","workload":"kmeans:buzz","target":"time","method":"bilal-x1","budget":10000,"seed":{seed},"trial_workers":1}}"#
    )
}

/// A client that disconnects mid-trial stops consuming measurement
/// budget within one pull: the reactor fires the connection's cancel
/// token on EOF, the trial's ledger observes it between pulls, and the
/// dataset's read counter plateaus far below the requested budget.
/// Readiness transports only — the thread-pinned fallback's worker is
/// parked inside the handler and cannot observe a mid-request EOF.
#[test]
fn mid_trial_disconnect_stops_budget_consumption() {
    if !json_leg() {
        return;
    }
    for transport in readiness_transports() {
        let name = transport.name();
        // Built by hand (not via `service()`): the test keeps its own
        // handle on the dataset to watch the read counter from outside.
        let ds = Arc::new(OfflineDataset::generate(60, 3));
        let svc = Service::new(Arc::clone(&ds), Arc::new(NativeBackend))
            .with_conn_workers(2)
            .with_transport(transport);
        let server = Server::start(svc);

        let mut doomed = server.connect();
        doomed.write_all(slow_optimize(1).as_bytes()).unwrap();
        doomed.write_all(b"\n").unwrap();
        doomed.flush().unwrap();
        std::thread::sleep(Duration::from_millis(150));
        drop(doomed); // EOF: the reactor fires the connection token.

        // The trial winds down at its next pull; the scheduler counts
        // the disconnect and the pulls the cancellation saved.
        let mut probe = server.connect();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let stats = parse(&roundtrip(&mut probe, r#"{"op":"stats"}"#)).unwrap();
            if stats.get("cancelled_disconnect").unwrap().as_usize().unwrap() >= 1 {
                assert!(
                    stats.get("pulls_saved").unwrap().as_usize().unwrap() >= 1,
                    "{name}: cancelling a 10k-budget trial mid-flight must save pulls"
                );
                break;
            }
            assert!(Instant::now() < deadline, "{name}: disconnect never cancelled the trial");
            std::thread::sleep(Duration::from_millis(50));
        }

        // Consumption really stopped: the read counter plateaus, well
        // short of what a run-to-completion trial would burn.
        let settled = ds.measurement_reads();
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(
            ds.measurement_reads(),
            settled,
            "{name}: reads must stop once the trial is cancelled"
        );
        assert!(
            settled < 10_000,
            "{name}: cancelled trial burned its whole budget ({settled} reads)"
        );
    }
}

/// With every normal-lane worker pinned by slow trials, control-plane
/// requests still answer promptly: the dispatcher's frame sniff routes
/// `stats` to the priority lane, where the team's dedicated priority
/// worker serves it without queueing behind the trials.
#[test]
fn saturated_workers_still_answer_stats_via_priority_lane() {
    if !json_leg() {
        return;
    }
    for transport in readiness_transports() {
        let name = transport.name();
        let server = Server::start(service().with_conn_workers(2).with_transport(transport));

        // Pin both normal-lane workers with big uncacheable trials.
        let busy: Vec<TcpStream> = (0..2u64)
            .map(|seed| {
                let mut conn = server.connect();
                conn.write_all(slow_optimize(seed).as_bytes()).unwrap();
                conn.write_all(b"\n").unwrap();
                conn.flush().unwrap();
                conn
            })
            .collect();
        std::thread::sleep(Duration::from_millis(300));

        // A fresh client's stats round-trip must not queue behind them.
        let started = Instant::now();
        let mut fresh = server.connect();
        let stats = parse(&roundtrip(&mut fresh, r#"{"op":"stats"}"#)).unwrap();
        let waited = started.elapsed();
        assert!(waited < Duration::from_secs(10), "{name}: stats stalled {waited:?}");
        assert!(
            stats.get("priority_served").unwrap().as_usize().unwrap() >= 1,
            "{name}: stats must ride the priority lane"
        );
        assert_eq!(
            stats.get("trials_run").unwrap().as_usize(),
            Some(0),
            "{name}: both trials still in flight — the answer did not wait for them"
        );

        // Dropping the busy clients fires their tokens, so the pinned
        // trials cancel and teardown (drain + join in Server::drop)
        // stays bounded instead of waiting out two 10k-pull searches.
        drop(busy);
    }
}

/// `deadline_ms: 0` (an already-expired deadline) cancels after the
/// guaranteed first pull in every transport × codec × reactor cell,
/// with byte-identical partial responses carrying
/// `"cancelled":"deadline"` — and the partial never enters the
/// response cache: a repeat re-runs the trial. Deadlines also work on
/// the threaded fallback (the deadline child token needs no reactor).
#[test]
fn deadline_cancelled_responses_are_deterministic_and_cache_excluded() {
    let req = r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":10,"seed":1,"measure_mode":"mean","trial_workers":1,"deadline_ms":0}"#;
    let mut reference: Option<String> = None;
    for codec in codecs() {
        let mut cells: Vec<(String, Server)> = Vec::new();
        for transport in transports() {
            if transport == Transport::Threaded {
                cells.push((
                    "threaded".to_string(),
                    Server::start(service().with_conn_workers(2).with_event_loop(false)),
                ));
            } else {
                for r in reactors() {
                    cells.push((
                        format!("{}/reactors={r}", transport.name()),
                        Server::start(
                            service()
                                .with_conn_workers(2)
                                .with_transport(transport)
                                .with_reactors(r),
                        ),
                    ));
                }
            }
        }
        for (name, server) in &cells {
            let mut conn = server.connect();
            if codec == "binary" {
                let ack = roundtrip(&mut conn, r#"{"op":"hello","codec":"binary"}"#);
                assert!(ack.contains("\"ok\":true"), "{name}: {ack}");
            }
            let first = roundtrip_codec(&mut conn, codec, req);
            assert!(first.contains("\"ok\":true"), "{name}/{codec}: {first}");
            assert!(
                first.contains("\"cancelled\":\"deadline\""),
                "{name}/{codec}: expired deadline must mark the partial: {first}"
            );
            match &reference {
                None => reference = Some(first.clone()),
                Some(expected) => assert_eq!(
                    &first, expected,
                    "{name}/{codec}: deadline partials must be byte-identical across cells"
                ),
            }

            // Cache-excluded: the repeat re-runs the trial (trials_run
            // reaches 2, zero cache hits) and answers identically.
            let second = roundtrip_codec(&mut conn, codec, req);
            assert_eq!(second, first, "{name}/{codec}: repeats must be deterministic");
            let stats = parse(&roundtrip_codec(&mut conn, codec, r#"{"op":"stats"}"#)).unwrap();
            assert_eq!(
                stats.get("trials_run").unwrap().as_usize(),
                Some(2),
                "{name}/{codec}: a cancelled partial must never be served from cache"
            );
            assert_eq!(stats.get("cache_hits").unwrap().as_usize(), Some(0), "{name}/{codec}");
            assert_eq!(
                stats.get("cancelled_deadline").unwrap().as_usize(),
                Some(2),
                "{name}/{codec}"
            );
            assert!(stats.get("pulls_saved").unwrap().as_usize().unwrap() >= 2, "{name}/{codec}");
        }
    }
}
