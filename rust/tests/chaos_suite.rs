//! Chaos suite: scripted fault schedules against the measurement path
//! and the dynamic-market `online` mode over real sockets.
//!
//! Pinned here:
//! * a mid-trial revocation is a **cancellation, not a crash**: the
//!   completed pull prefix is bit-identical to the unrevoked run, the
//!   reason reads `"revoked"`, and `pulls_saved` accounts the entire
//!   unspent budget — run twice, byte-identical both times;
//! * first-cancel-wins under races the schedule makes deterministic:
//!   revocation vs an expired deadline and revocation vs a disconnect,
//!   in both orders;
//! * an injected measurement panic is contained by `catch_unwind` and
//!   the process worker team stays usable (the next clean trial matches
//!   the fault-free reference exactly);
//! * slow/stalled sources degrade gracefully: same bits, just later;
//! * the `online` op answers byte-identically across every
//!   transport × codec × reactor cell, repeats re-run the trial (online
//!   responses never touch the response cache), and an expired deadline
//!   yields a deterministic one-tick partial;
//! * `stats` cache counters are mutually consistent under a multi-shard
//!   write hammer: `cache_inserts - cache_evictions ==
//!   cached_responses` in every snapshot taken mid-load.
//!
//! CI runs this file in the plain test job and once per transport under
//! `SERVICE_CHAOS=1` with `SERVICE_TRANSPORT` narrowing the matrix —
//! the same env contract as `service_suite` (helpers mirrored from
//! there).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use multicloud::coordinator::service::{Service, Transport};
use multicloud::dataset::objective::{EvalLedger, EvalSource, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::surrogate::NativeBackend;
use multicloud::util::cancel::{CancelReason, CancelToken};
use multicloud::util::chaos::{ChaosSource, Fault, FaultSchedule};
use multicloud::util::json::parse;
use multicloud::util::rng::Rng;

fn service() -> Service {
    let ds = Arc::new(OfflineDataset::generate(60, 3));
    Service::new(ds, Arc::new(NativeBackend))
}

fn transports() -> Vec<Transport> {
    let mut out = Vec::new();
    if multicloud::util::net::epoll_supported() {
        out.push(Transport::Epoll);
    }
    if multicloud::util::net::supported() {
        out.push(Transport::Poll);
    }
    out.push(Transport::Threaded);
    if let Ok(only) = std::env::var("SERVICE_TRANSPORT") {
        if !only.is_empty() {
            out.retain(|t| t.name() == only);
        }
    }
    out
}

fn codecs() -> Vec<&'static str> {
    let mut out = vec!["json", "binary"];
    if let Ok(only) = std::env::var("SERVICE_CODEC") {
        if !only.is_empty() {
            out.retain(|c| *c == only);
        }
    }
    out
}

fn reactors() -> Vec<usize> {
    let mut out = vec![1usize, 4];
    if let Ok(only) = std::env::var("SERVICE_REACTORS") {
        if let Ok(n) = only.trim().parse::<usize>() {
            out.retain(|r| *r == n);
        }
    }
    out
}

struct Server {
    svc: Arc<Service>,
    stop: Arc<AtomicBool>,
    port: u16,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    fn start(svc: Service) -> Server {
        let svc = Arc::new(svc);
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) =
            Arc::clone(&svc).serve("127.0.0.1:0", Arc::clone(&stop)).expect("bind");
        Server { svc, stop, port, handle: Some(handle) }
    }

    fn connect(&self) -> TcpStream {
        let conn = TcpStream::connect(("127.0.0.1", self.port)).expect("connect");
        conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        conn
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn roundtrip(conn: &mut TcpStream, line: &str) -> String {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    conn.flush().unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut out = String::new();
    reader.read_line(&mut out).expect("read response");
    out.trim_end().to_string()
}

fn write_binary_frame(conn: &mut TcpStream, payload: &[u8]) {
    conn.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
    conn.write_all(payload).unwrap();
    conn.flush().unwrap();
}

fn read_binary_frame(conn: &mut TcpStream) -> String {
    let mut len = [0u8; 4];
    conn.read_exact(&mut len).expect("read frame length");
    let mut payload = vec![0u8; u32::from_le_bytes(len) as usize];
    conn.read_exact(&mut payload).expect("read frame payload");
    String::from_utf8(payload).expect("response payload is JSON text")
}

fn roundtrip_codec(conn: &mut TcpStream, codec: &str, line: &str) -> String {
    if codec == "binary" {
        write_binary_frame(conn, line.as_bytes());
        read_binary_frame(conn)
    } else {
        roundtrip(conn, line)
    }
}

/// Run one `rs` trial against `source`, returning the ledger for
/// inspection. Sequential (`arm_workers` 1) so panic propagation and
/// pull order are exactly the schedule's tick order.
fn run_rs<'a>(
    ds: &OfflineDataset,
    source: &'a dyn EvalSource,
    budget: usize,
    cancel: Option<CancelToken>,
) -> EvalLedger<'a> {
    let backend = NativeBackend;
    let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
    let mut ledger = EvalLedger::new(source, budget);
    if let Some(token) = cancel {
        ledger = ledger.with_cancel(token);
    }
    let mut rng = Rng::new(13);
    by_name("rs").unwrap().run(&ctx, &mut ledger, &mut rng);
    ledger
}

/// The acceptance criterion: a revocation mid-trial terminates the arm
/// through cancellation — completed pulls bit-identical to the
/// unrevoked run, reason `"revoked"`, the unspent budget accounted in
/// `pulls_saved` — and the whole chaotic run replays byte-identically.
#[test]
fn revoked_trial_is_a_cancellation_with_a_bit_identical_prefix() {
    let ds = OfflineDataset::generate(60, 3);
    let budget = 12;
    let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 7);

    let clean = run_rs(&ds, &src, budget, None);
    assert_eq!(clean.cancelled(), None);
    assert_eq!(clean.evals(), budget);
    let clean_trace: Vec<u64> = clean.trace().iter().map(|v| v.to_bits()).collect();

    let mut runs = Vec::new();
    for _ in 0..2 {
        let token = CancelToken::new();
        let chaos =
            ChaosSource::new(&src, FaultSchedule::new().at(5, Fault::Revoke), token.clone());
        let ledger = run_rs(&ds, &chaos, budget, Some(token));
        assert_eq!(ledger.cancelled(), Some("revoked"));
        // The revoking pull (tick 5, the sixth measurement) completes;
        // the ledger refuses the seventh.
        assert_eq!(ledger.evals(), 6);
        assert_eq!(ledger.pulls_saved(), budget - 6, "no budget leak");
        let trace: Vec<u64> = ledger.trace().iter().map(|v| v.to_bits()).collect();
        assert_eq!(trace, clean_trace[..6], "completed prefix diverged from unrevoked run");
        assert_eq!(ledger.history(), &clean.history()[..6]);
        runs.push((trace, ledger.total_expense().to_bits()));
    }
    assert_eq!(runs[0], runs[1], "same schedule, same bytes");
}

/// Slow and stalled sources change latency, never results.
#[test]
fn slow_and_stalled_sources_degrade_gracefully() {
    let ds = OfflineDataset::generate(60, 3);
    let src = LookupObjective::new(&ds, 1, Target::Cost, MeasureMode::SingleDraw, 3);
    let clean = run_rs(&ds, &src, 6, None);

    let schedule = FaultSchedule::parse("1:slow=5,3:stall=10").unwrap();
    let chaos = ChaosSource::new(&src, schedule, CancelToken::new());
    let started = Instant::now();
    let slow = run_rs(&ds, &chaos, 6, None);
    assert!(started.elapsed() >= Duration::from_millis(15), "faults must actually delay");
    assert_eq!(slow.cancelled(), None);
    assert_eq!(slow.history(), clean.history());
    assert_eq!(slow.total_expense().to_bits(), clean.total_expense().to_bits());
}

/// Revocation vs deadline, both orders — deterministic via the
/// schedule. A revocation that fires before the deadline is ever
/// observed wins even though the deadline already expired on the clock;
/// a deadline observed between pulls before the revocation tick wins
/// and the revocation never fires.
#[test]
fn revocation_under_deadline_first_cancel_wins() {
    let ds = OfflineDataset::generate(60, 3);
    let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 5);

    // Revoke during the guaranteed first pull: the token latches
    // "revoked" before any between-pull deadline check runs.
    let token = CancelToken::new().with_deadline(Instant::now());
    let chaos = ChaosSource::new(&src, FaultSchedule::new().at(0, Fault::Revoke), token.clone());
    let ledger = run_rs(&ds, &chaos, 8, Some(token.clone()));
    assert_eq!(ledger.cancelled(), Some("revoked"));
    assert_eq!(token.reason(), Some(CancelReason::Revoked));
    assert_eq!(ledger.evals(), 1);

    // Revocation scheduled past the horizon the deadline allows: the
    // expired deadline is observed after pull 0 and the revoke tick is
    // never reached.
    let token = CancelToken::new().with_deadline(Instant::now());
    let chaos = ChaosSource::new(&src, FaultSchedule::new().at(4, Fault::Revoke), token.clone());
    let ledger = run_rs(&ds, &chaos, 8, Some(token.clone()));
    assert_eq!(ledger.cancelled(), Some("deadline"));
    assert_eq!(token.reason(), Some(CancelReason::Deadline));
    assert_eq!(ledger.evals(), 1);
    assert_eq!(chaos.ticks(), 1, "revocation tick never reached");
}

/// Revocation vs disconnect, both orders: whichever cancels first names
/// the reason; the loser's cancel reports `false` and changes nothing.
#[test]
fn revocation_under_disconnect_first_cancel_wins() {
    let ds = OfflineDataset::generate(60, 3);
    let src = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, 9);

    // Disconnect before the trial starts; the revoke at tick 0 fires
    // during the guaranteed pull and loses.
    let token = CancelToken::new();
    assert!(token.cancel(CancelReason::Disconnect));
    let chaos = ChaosSource::new(&src, FaultSchedule::new().at(0, Fault::Revoke), token.clone());
    let ledger = run_rs(&ds, &chaos, 8, Some(token.clone()));
    assert_eq!(ledger.cancelled(), Some("disconnect"));
    assert_eq!(token.reason(), Some(CancelReason::Disconnect));
    assert_eq!(ledger.evals(), 1);

    // Revoke first; a disconnect arriving after the trial wound down
    // must not rewrite history.
    let token = CancelToken::new();
    let chaos = ChaosSource::new(&src, FaultSchedule::new().at(2, Fault::Revoke), token.clone());
    let ledger = run_rs(&ds, &chaos, 8, Some(token.clone()));
    assert_eq!(ledger.cancelled(), Some("revoked"));
    assert!(!token.cancel(CancelReason::Disconnect), "late disconnect must lose");
    assert_eq!(token.reason(), Some(CancelReason::Revoked));
    assert_eq!(ledger.evals(), 3);
}

/// An injected measurement panic is contained by `catch_unwind`, and
/// the process worker team serves the next clean trial with results
/// identical to the fault-free reference.
#[test]
fn injected_panic_is_contained_and_the_team_stays_usable() {
    let ds = OfflineDataset::generate(60, 3);
    let src = LookupObjective::new(&ds, 4, Target::Cost, MeasureMode::SingleDraw, 11);
    let reference = run_rs(&ds, &src, 8, None);

    let chaos =
        ChaosSource::new(&src, FaultSchedule::new().at(3, Fault::Panic), CancelToken::new());
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_rs(&ds, &chaos, 8, None);
    }));
    assert!(outcome.is_err(), "the scripted panic must surface");

    let after = run_rs(&ds, &src, 8, None);
    assert_eq!(after.history(), reference.history(), "team unusable after contained panic");
}

/// The `online` op across the transport × codec × reactor matrix:
/// byte-identical in every cell and on repeat, regret trace sized to
/// the horizon, Pareto front attached on request, and the response
/// cache never involved.
#[test]
fn online_mode_over_sockets_is_deterministic_across_the_matrix() {
    let req = concat!(
        r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","#,
        r#""budget":8,"seed":3,"measure_mode":"mean","#,
        r#""online":{"ticks":6,"reoptimize_every":2},"#,
        r#""include_trace":true,"include_pareto":true}"#
    );
    let mut reference: Option<String> = None;
    for codec in codecs() {
        let mut cells: Vec<(String, Server)> = Vec::new();
        for transport in transports() {
            if transport == Transport::Threaded {
                let server =
                    Server::start(service().with_conn_workers(2).with_transport(transport));
                cells.push((transport.name().to_string(), server));
            } else {
                for r in reactors() {
                    cells.push((
                        format!("{}/reactors={r}", transport.name()),
                        Server::start(
                            service()
                                .with_conn_workers(2)
                                .with_transport(transport)
                                .with_reactors(r),
                        ),
                    ));
                }
            }
        }
        for (name, server) in &cells {
            let mut conn = server.connect();
            if codec == "binary" {
                let ack = roundtrip(&mut conn, r#"{"op":"hello","codec":"binary"}"#);
                assert!(ack.contains("\"ok\":true"), "{name}: {ack}");
            }
            let first = roundtrip_codec(&mut conn, codec, req);
            assert!(first.contains("\"ok\":true"), "{name}/{codec}: {first}");
            assert!(first.contains("\"mode\":\"online\""), "{name}/{codec}: {first}");
            let body = parse(&first).unwrap();
            assert_eq!(body.get("ticks").unwrap().as_usize(), Some(6), "{name}/{codec}");
            assert_eq!(
                body.get("trace").unwrap().as_arr().unwrap().len(),
                6,
                "{name}/{codec}: one regret point per tick"
            );
            assert!(
                !body.get("pareto").unwrap().as_arr().unwrap().is_empty(),
                "{name}/{codec}: Pareto front missing"
            );
            assert!(
                body.get("revocations").unwrap().as_arr().is_some(),
                "{name}/{codec}: revocation schedule missing"
            );
            match &reference {
                None => reference = Some(first.clone()),
                Some(expected) => assert_eq!(
                    &first, expected,
                    "{name}/{codec}: online responses must be byte-identical across cells"
                ),
            }

            // Online is cache-excluded: the repeat re-runs the trial and
            // still answers identically.
            let second = roundtrip_codec(&mut conn, codec, req);
            assert_eq!(second, first, "{name}/{codec}: online repeat diverged");
            let stats = parse(&roundtrip_codec(&mut conn, codec, r#"{"op":"stats"}"#)).unwrap();
            assert_eq!(stats.get("trials_run").unwrap().as_usize(), Some(2), "{name}/{codec}");
            assert_eq!(stats.get("cache_hits").unwrap().as_usize(), Some(0), "{name}/{codec}");
            assert_eq!(
                stats.get("cached_responses").unwrap().as_usize(),
                Some(0),
                "{name}/{codec}: online responses must never be cached"
            );
        }
    }
}

/// An online request under an already-expired deadline is a
/// deterministic one-tick partial, marked `cancelled: "deadline"`.
#[test]
fn online_under_expired_deadline_is_a_deterministic_partial() {
    let svc = service();
    let req = concat!(
        r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","#,
        r#""budget":8,"seed":2,"measure_mode":"mean","deadline_ms":0,"#,
        r#""online":{"ticks":5,"reoptimize_every":2},"include_trace":true}"#
    );
    let first = svc.handle(req);
    assert!(first.contains("\"cancelled\":\"deadline\""), "{first}");
    let body = parse(&first).unwrap();
    assert_eq!(body.get("ticks").unwrap().as_usize(), Some(1), "stops after the scored tick");
    assert_eq!(body.get("trace").unwrap().as_arr().unwrap().len(), 1);
    assert_eq!(body.get("evals").unwrap().as_usize(), Some(1), "the guaranteed first pull");
    assert_eq!(svc.handle(req), first, "expired-deadline partials must be deterministic");
}

/// Satellite: `stats` cache counters under a multi-stripe write hammer.
/// Every snapshot taken during load must satisfy
/// `cache_inserts - cache_evictions == cached_responses` — the
/// lock-consistent aggregation this PR introduces (pre-fix, the sums
/// interleaved with writers and the identity broke).
#[test]
fn striped_cache_stats_are_consistent_under_hammer() {
    for shards in [2usize, 5] {
        let svc = service().with_cache_shards(shards).with_cache_cap(12);
        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| {
            let writers: Vec<_> = (0..4u64)
                .map(|w| {
                    let svc = &svc;
                    scope.spawn(move || {
                        for i in 0..120u64 {
                            let seed = (w * 120 + i) % 40;
                            let req = format!(
                                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"rs","budget":2,"seed":{seed},"measure_mode":"mean"}}"#
                            );
                            let resp = svc.handle(&req);
                            assert!(resp.contains("\"ok\":true"), "{resp}");
                        }
                    })
                })
                .collect();
            let reader = {
                let svc = &svc;
                let stop = &stop;
                scope.spawn(move || {
                    let mut last_hits_misses = 0u64;
                    let mut snapshots = 0u32;
                    while !stop.load(Ordering::Acquire) {
                        let stats = parse(&svc.handle(r#"{"op":"stats"}"#)).unwrap();
                        let get = |k: &str| stats.get(k).unwrap().as_usize().unwrap() as u64;
                        let (inserts, evictions) = (get("cache_inserts"), get("cache_evictions"));
                        let resident = get("cached_responses");
                        assert_eq!(
                            inserts as i64 - evictions as i64,
                            resident as i64,
                            "shards={shards}: snapshot identity broke under load"
                        );
                        assert!(resident <= 12, "shards={shards}: residency above cap");
                        let hits_misses = get("cache_hits") + get("cache_misses");
                        assert!(
                            hits_misses >= last_hits_misses,
                            "shards={shards}: hit/miss counters went backwards"
                        );
                        last_hits_misses = hits_misses;
                        snapshots += 1;
                    }
                    snapshots
                })
            };
            for w in writers {
                w.join().unwrap();
            }
            stop.store(true, Ordering::Release);
            let snapshots = reader.join().unwrap();
            assert!(snapshots > 0, "reader never snapshotted during load");
        });
        // The 40-key set against a 12-entry cap guarantees real
        // evictions happened, so the identity was exercised non-trivially.
        let end = svc.scheduler().cache_stats();
        assert!(end.evictions > 0, "shards={shards}: hammer never evicted");
        assert_eq!(
            end.inserts - end.evictions,
            end.resident as u64,
            "shards={shards}: final snapshot inconsistent"
        );
    }
}
