//! Integration: the PJRT artifact backend must agree with the native Rust
//! surrogates — the AOT graphs and the native code implement the same
//! math, so disagreement means one of them is wrong.
//!
//! Requires `make artifacts` to have run (skipped with a loud message
//! otherwise, so `cargo test` works in a fresh checkout).

use multicloud::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::domain::encode;
use multicloud::linalg::Matrix;
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::runtime::ArtifactBackend;
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::rng::Rng;

fn load_backend() -> Option<ArtifactBackend> {
    let dir = multicloud::runtime::artifact_dir(None);
    match ArtifactBackend::load(&dir) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIPPING artifact parity tests: {e} (run `make artifacts`)");
            None
        }
    }
}

/// Sample n encoded observations + targets from a real workload surface.
fn sample_problem(n: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let ds = OfflineDataset::generate(77, 3);
    let grid = ds.domain.full_grid();
    let mut rng = Rng::new(seed);
    let idx = rng.sample_indices(grid.len(), n);
    let x = Matrix::from_rows(
        &idx.iter().map(|&i| encode(&ds.domain, &grid[i])).collect::<Vec<Vec<f64>>>(),
    );
    let y: Vec<f64> = idx.iter().map(|&i| ds.mean_value(3, i, Target::Cost)).collect();
    let cands = Matrix::from_rows(
        &grid.iter().map(|c| encode(&ds.domain, c)).collect::<Vec<Vec<f64>>>(),
    );
    (x, y, cands)
}

#[test]
fn gp_artifact_matches_native_posterior() {
    let Some(backend) = load_backend() else { return };
    let native = NativeBackend;
    for (n, seed) in [(4usize, 1u64), (16, 2), (40, 3), (88, 4)] {
        let (x, y, cands) = sample_problem(n, seed);
        let pa = backend.gp_fit_predict(&x, &y, &cands);
        let pn = native.gp_fit_predict(&x, &y, &cands);
        let scale = y.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1e-9);
        for i in 0..cands.rows {
            let dm = (pa.mean[i] - pn.mean[i]).abs() / scale;
            assert!(dm < 2e-3, "n={n} cand {i}: mean {} vs {}", pa.mean[i], pn.mean[i]);
            let ds_ = (pa.std[i] - pn.std[i]).abs() / scale;
            assert!(ds_ < 2e-3, "n={n} cand {i}: std {} vs {}", pa.std[i], pn.std[i]);
        }
    }
}

#[test]
fn rbf_artifact_matches_native_interpolant() {
    let Some(backend) = load_backend() else { return };
    let native = NativeBackend;
    for (n, seed) in [(5usize, 5u64), (20, 6), (60, 7)] {
        let (x, y, cands) = sample_problem(n, seed);
        // Normalize targets: the artifact solves in f64 but f32 interface
        // limits the dynamic range of raw costs.
        let (z, _, _) = multicloud::surrogate::standardize(&y);
        let pa = backend.rbf_fit_predict(&x, &z, 1e-6, &cands);
        let pn = native.rbf_fit_predict(&x, &z, 1e-6, &cands);
        for i in 0..cands.rows {
            assert!(
                (pa.pred[i] - pn.pred[i]).abs() < 5e-2,
                "n={n} cand {i}: pred {} vs {}",
                pa.pred[i],
                pn.pred[i]
            );
            assert!(
                (pa.mindist[i] - pn.mindist[i]).abs() < 1e-3,
                "n={n} cand {i}: mindist {} vs {}",
                pa.mindist[i],
                pn.mindist[i]
            );
        }
    }
}

#[test]
fn optimizers_run_end_to_end_on_artifact_backend() {
    let Some(backend) = load_backend() else { return };
    let ds = OfflineDataset::generate(123, 3);
    for name in ["cherrypick-x1", "cb-rbfopt", "cb-cherrypick"] {
        let opt = by_name(name).unwrap();
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 9, Target::Cost, MeasureMode::SingleDraw, 11);
        let mut ledger = EvalLedger::new(&src, 22);
        let mut rng = Rng::new(13);
        let res = opt.run(&ctx, &mut ledger, &mut rng);
        assert!(ledger.evals() <= 22);
        assert!(res.best_value.is_finite(), "{name}");
        // Search should do clearly better than the domain average.
        assert!(res.best_value < ds.random_strategy_value(9, Target::Cost), "{name}");
    }
}

#[test]
fn artifact_and_native_agree_on_proposals_early() {
    // Stronger end-to-end parity: with identical seeds and deterministic
    // objective mode, a short CherryPick run should pick the same configs
    // under both backends (ties aside, the acquisitions agree to ~1e-3).
    let Some(backend) = load_backend() else { return };
    let ds = OfflineDataset::generate(55, 3);
    let run = |b: &dyn Backend| {
        let opt = by_name("cherrypick-x1").unwrap();
        let ctx = SearchContext::new(&ds.domain, Target::Time, b);
        let src = LookupObjective::new(&ds, 20, Target::Time, MeasureMode::Mean, 7);
        let mut ledger = EvalLedger::new(&src, 12);
        let mut rng = Rng::new(99);
        opt.run(&ctx, &mut ledger, &mut rng).best_value
    };
    let va = run(&backend);
    let vn = run(&NativeBackend);
    let rel = (va - vn).abs() / vn.abs().max(1e-9);
    assert!(rel < 0.2, "artifact best {va} vs native best {vn}");
}
