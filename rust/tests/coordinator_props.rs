//! Property-based integration tests on coordinator invariants (testkit —
//! the proptest substitute; see DESIGN.md §Substitutions).

use multicloud::coordinator::experiment::{run_trial, TrialSpec};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::optimizers::cloudbandit::b1_for_budget;
use multicloud::optimizers::ALL_OPTIMIZERS;
use multicloud::runtime::artifacts::Manifest;
use multicloud::surrogate::NativeBackend;
use multicloud::testkit;

fn dataset() -> &'static OfflineDataset {
    use std::sync::OnceLock;
    static DS: OnceLock<OfflineDataset> = OnceLock::new();
    DS.get_or_init(|| OfflineDataset::generate(2022, 3))
}

#[test]
fn prop_no_optimizer_exceeds_its_budget() {
    let ds = dataset();
    let backend = NativeBackend;
    testkit::check("budget ceiling", 40, |g| {
        let method = g.pick(&ALL_OPTIMIZERS).to_string();
        if method == "exhaustive" {
            return; // provisions the full grid by definition (its ledger
                    // is sized to domain.size(), not the nominal budget)
        }
        let spec = TrialSpec {
            method,
            workload: g.usize_in(0, 29),
            target: if g.bool() { Target::Time } else { Target::Cost },
            budget: g.usize_in(1, 40),
            seed: g.usize_in(0, 1000) as u64,
            // Budget enforcement must hold under parallel arm execution
            // too: shard reservations share one atomic pool.
            trial_workers: g.usize_in(1, 4),
            ..TrialSpec::default()
        };
        let r = run_trial(ds, &backend, &spec);
        assert!(
            r.evals <= spec.budget,
            "{} (workers={}) used {} > budget {}",
            r.spec.method,
            spec.trial_workers,
            r.evals,
            spec.budget
        );
        assert!(r.regret >= -1e-12, "negative regret {}", r.regret);
        assert!(r.search_expense > 0.0);
    });
}

#[test]
fn prop_trials_are_replayable() {
    let ds = dataset();
    let backend = NativeBackend;
    testkit::check("trial determinism", 15, |g| {
        let spec = TrialSpec {
            method: g
                .pick(&["rs", "smac", "cb-rbfopt", "cb-cherrypick", "rb", "hyperopt"])
                .to_string(),
            workload: g.usize_in(0, 29),
            target: Target::Cost,
            budget: g.usize_in(5, 25),
            seed: g.usize_in(0, 99) as u64,
            ..TrialSpec::default()
        };
        let a = run_trial(ds, &backend, &spec);
        let b = run_trial(ds, &backend, &spec);
        assert_eq!(a.regret, b.regret);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.search_expense, b.search_expense);
        // Parallel arm execution is part of the determinism contract:
        // any worker count replays the sequential trial bit-for-bit.
        let par = run_trial(
            ds,
            &backend,
            &TrialSpec { trial_workers: g.usize_in(2, 4), ..spec.clone() },
        );
        assert_eq!(a.regret.to_bits(), par.regret.to_bits(), "{}", spec.method);
        assert_eq!(a.evals, par.evals, "{}", spec.method);
        assert_eq!(
            a.search_expense.to_bits(),
            par.search_expense.to_bits(),
            "{}",
            spec.method
        );
        assert_eq!(a.chosen_value.to_bits(), par.chosen_value.to_bits(), "{}", spec.method);
    });
}

#[test]
fn prop_cloudbandit_schedule_fits_budget() {
    testkit::check("CB schedule unit", 100, |g| {
        let k = g.usize_in(2, 6);
        let eta = g.f64_in(1.0, 3.0);
        let budget = g.usize_in(1, 500);
        let b1 = b1_for_budget(budget, k, eta);
        assert!(b1 >= 1);
        // The scheduled total with this b1 must not exceed the budget
        // unless b1 was clamped to its minimum of 1.
        let total: f64 =
            (1..=k).map(|m| (k - m + 1) as f64 * b1 as f64 * eta.powi(m as i32 - 1)).sum();
        assert!(
            total <= budget as f64 + 1e-9 || b1 == 1,
            "schedule {total} exceeds budget {budget} with b1={b1}"
        );
    });
}

#[test]
fn prop_savings_upper_bound() {
    testkit::check("savings <= 1", 200, |g| {
        let c_opt = g.f64_in(0.0, 100.0);
        let r_opt = g.f64_in(0.0, 10.0);
        let r_rand = g.f64_in(0.1, 10.0);
        let n = g.usize_in(1, 1000);
        let s = multicloud::metrics::savings(c_opt, r_opt, r_rand, n);
        assert!(s <= 1.0 + 1e-12, "savings {s} > 1");
        // Optimal config + zero search cost attains the bound only when
        // r_opt == 0.
        if r_opt == 0.0 && c_opt == 0.0 {
            assert!((s - 1.0).abs() < 1e-12);
        }
    });
}

#[test]
fn manifest_parser_rejects_corruption() {
    let good = r#"{"version":2,"n_max":96,"m_max":96,"d":20,
        "graphs":{"gp_matern52":{"file":"gp.hlo.txt"},
                  "rbf_cubic":{"file":"rbf.hlo.txt"}}}"#;
    let m = Manifest::parse(good).unwrap();
    assert_eq!(m.n_max, 96);
    assert_eq!(m.gp_file, "gp.hlo.txt");

    for bad in [
        "",
        "{}",
        r#"{"version":2}"#,
        r#"{"version":2,"n_max":96,"m_max":96,"d":20,"graphs":{}}"#,
        r#"{"version":2,"n_max":96,"m_max":96,"d":20,
            "graphs":{"gp_matern52":{"file":"gp.hlo.txt"}}}"#,
        r#"{"version":2,"n_max":"lots","m_max":96,"d":20,
            "graphs":{"gp_matern52":{"file":"a"},"rbf_cubic":{"file":"b"}}}"#,
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn backend_fallback_on_missing_artifacts() {
    // Loading from a directory without artifacts must error (the CLI then
    // falls back to native), never panic.
    let r = multicloud::runtime::ArtifactBackend::load("/nonexistent/dir");
    assert!(r.is_err());
}
