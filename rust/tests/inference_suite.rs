//! Integration: the ML-inference workload suite (the paper's stated
//! future work, implemented as an extension — DESIGN.md §4 note).
//! Verifies the suite has the expected shape, that the full optimizer
//! pipeline runs on it unchanged, and that the headline savings behaviour
//! (CloudBandit positive, exhaustive negative) carries over to the new
//! workload category.

use multicloud::coordinator::savings::{savings_analysis, SavingsConfig};
use multicloud::dataset::{OfflineDataset, Target};
use multicloud::optimizers::{by_name, SearchContext};
use multicloud::simulator::tasks::inference_workloads;
use multicloud::surrogate::NativeBackend;

#[test]
fn suite_shape() {
    let ws = inference_workloads();
    assert_eq!(ws.len(), 10);
    let mut ids: Vec<String> = ws.iter().map(|w| w.id()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 10);

    let ds = OfflineDataset::generate_inference(7, 3);
    assert_eq!(ds.workload_count(), 10);
    assert_eq!(ds.domain.size(), 88);
    assert!(ds.workload_index("bert_serving:peak_trace").is_some());
}

#[test]
fn optimizers_run_on_inference_workloads() {
    let ds = OfflineDataset::generate_inference(8, 3);
    let backend = NativeBackend;
    for name in ["rs", "smac", "cb-rbfopt", "hyperopt"] {
        let opt = by_name(name).unwrap();
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let src = multicloud::dataset::objective::LookupObjective::new(
            &ds,
            2,
            Target::Time,
            multicloud::dataset::objective::MeasureMode::SingleDraw,
            5,
        );
        let mut ledger = multicloud::dataset::objective::EvalLedger::new(&src, 22);
        let mut rng = multicloud::util::rng::Rng::new(6);
        let r = opt.run(&ctx, &mut ledger, &mut rng);
        assert_eq!(ledger.evals(), 22, "{name}");
        assert!(r.best_value.is_finite(), "{name}");
        assert!(r.best_value < ds.random_strategy_value(2, Target::Time) * 1.5, "{name}");
    }
}

#[test]
fn savings_shape_carries_over() {
    let ds = OfflineDataset::generate_inference(9, 3);
    let backend = NativeBackend;
    let cfg = SavingsConfig { seeds: 3, workers: 2, ..Default::default() };
    let dists = savings_analysis(
        &ds,
        &backend,
        &["cb-rbfopt".to_string(), "exhaustive".to_string()],
        Target::Cost,
        &cfg,
    );
    let cb = dists[0].box_stats();
    let ex = dists[1].box_stats();
    assert!(cb.median > 0.0, "cb median {:.3}", cb.median);
    assert!(ex.median < 0.0, "exhaustive median {:.3}", ex.median);
}

#[test]
fn memory_floor_separates_lean_and_fat_nodes() {
    // recsys_ranking on the peak trace needs ~22 GB resident; highcpu
    // 2-vCPU nodes (2 GB) must be dramatically slower than highmem ones.
    let ds = OfflineDataset::generate_inference(10, 3);
    let w = ds.workload_index("recsys_ranking:peak_trace").unwrap();
    let grid = ds.domain.full_grid();
    let find = |needle: &str| {
        grid.iter()
            .position(|c| c.label(&ds.domain) == needle)
            .unwrap_or_else(|| panic!("{needle} not in grid"))
    };
    let lean = find("gcp/family=n1/type=highcpu/vcpu=2/nodes=2");
    let fat = find("gcp/family=n1/type=highmem/vcpu=2/nodes=2");
    let t_lean = ds.mean_value(w, lean, Target::Time);
    let t_fat = ds.mean_value(w, fat, Target::Time);
    assert!(t_lean > 2.0 * t_fat, "lean {t_lean} vs fat {t_fat}");
}
