//! Surrogate models + acquisition functions for the BO-family optimizers.
//!
//! All surrogates share one interface: fit on the evaluated (encoded)
//! configurations, predict mean/std over a candidate set. Implementations:
//!
//! * [`gp::GpSurrogate`]   — Matern-5/2 Gaussian process (CherryPick, the
//!   Bilal et al. cost scheme, Rising Bandits' component optimizer). Runs
//!   natively (this module) or through the AOT PJRT artifact
//!   (`runtime::ArtifactGp`) — bit-for-bit the same math, checked by the
//!   parity integration test.
//! * [`rf::RandomForest`]  — random-forest regressor (Bilal et al. time
//!   scheme, SMAC-lite; also the PARIS-style predictor).
//! * [`rbf::RbfModel`]     — cubic RBF interpolant (RBFOpt-lite).
//! * [`tpe`]               — Parzen categorical estimators (HyperOpt-lite).

pub mod gp;
pub mod rbf;
pub mod rf;
pub mod tpe;

use crate::linalg::Matrix;

/// Pluggable execution backend for the two surrogates that exist both
/// natively and as AOT artifacts. The optimizer layer only ever talks to
/// this trait; `NativeBackend` computes in-process, `runtime::ArtifactGp`
/// executes the PJRT-compiled HLO. RF/TPE are native-only by design (the
/// paper's hot-spot is the GP/RBF math).
///
/// Observations and candidates travel as row-major [`Matrix`] values
/// (one encoded configuration per row), so the distance kernels on the
/// hot path stream over contiguous memory instead of pointer-chasing
/// nested `Vec`s.
pub trait Backend: Sync {
    /// Matern-5/2 GP posterior over candidates (mean/std in y units).
    fn gp_fit_predict(&self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction;

    /// Cubic-RBF interpolant values + min-distance over candidates.
    fn rbf_fit_predict(
        &self,
        x: &Matrix,
        y: &[f64],
        ridge: f64,
        cands: &Matrix,
    ) -> rbf::RbfPrediction;

    /// Open a stateful GP session for one search run: observations arrive
    /// one at a time and each `predict` conditions on everything so far.
    /// The default replays a full fit through [`gp_fit_predict`] (the
    /// reference semantics); `NativeBackend` overrides it with the O(n²)
    /// incremental-Cholesky session ([`gp::IncrementalGp`]). Sessions are
    /// `Send` so bandit arms can carry them onto worker threads.
    fn gp_session(&self) -> Box<dyn GpSession + Send + '_> {
        Box::new(ReplayGpSession {
            backend: self,
            x: Matrix::zeros(0, 0),
            y: Vec::new(),
            pinned: Matrix::zeros(0, 0),
        })
    }
}

/// A stateful GP fit that grows one observation at a time. Semantically a
/// session with n observations is interchangeable with a fresh
/// [`Backend::gp_fit_predict`] on the same data (asserted to 1e-6 by the
/// incremental/full parity tests) — only the fit cost differs.
pub trait GpSession {
    /// Record one (encoded configuration, observed value) pair.
    fn observe(&mut self, x: Vec<f64>, y: f64);

    /// Posterior mean/std over candidates (one encoded configuration per
    /// row) given all observations so far.
    fn predict(&mut self, cands: &Matrix) -> Prediction;

    /// Pin the session to a fixed candidate set. BO loops predict over
    /// the same grid every iteration, so sessions may precompute and
    /// cache per-candidate state (the native session caches the
    /// observation-candidate distance/kernel rows plus the whitened
    /// candidate matrix `L⁻¹K(X, C)`, all grown one row per `observe`).
    /// Predictions over the pinned set come from
    /// [`predict_pinned`](Self::predict_pinned) and agree with `predict`
    /// on the same candidates within the 1e-6 parity contract (the
    /// whitened mean accumulates in a different — algebraically
    /// identical — order).
    fn pin_candidates(&mut self, cands: &Matrix);

    /// Posterior over the pinned candidate set. Panics if no set was
    /// pinned.
    fn predict_pinned(&mut self) -> Prediction;

    /// Number of observations recorded.
    fn n_obs(&self) -> usize;
}

/// Full-refit reference session: buffers observations and delegates every
/// `predict` to the backend's one-shot fit. Used by backends without an
/// incremental path (the PJRT artifact executes fixed-shape graphs).
pub struct ReplayGpSession<'a, B: Backend + ?Sized> {
    backend: &'a B,
    x: Matrix,
    y: Vec<f64>,
    pinned: Matrix,
}

impl<B: Backend + ?Sized> GpSession for ReplayGpSession<'_, B> {
    fn observe(&mut self, x: Vec<f64>, y: f64) {
        self.x.push_row(&x);
        self.y.push(y);
    }

    fn predict(&mut self, cands: &Matrix) -> Prediction {
        self.backend.gp_fit_predict(&self.x, &self.y, cands)
    }

    fn pin_candidates(&mut self, cands: &Matrix) {
        self.pinned = cands.clone();
    }

    fn predict_pinned(&mut self) -> Prediction {
        assert!(self.pinned.rows > 0, "predict_pinned without pinned candidates");
        self.backend.gp_fit_predict(&self.x, &self.y, &self.pinned)
    }

    fn n_obs(&self) -> usize {
        self.y.len()
    }
}

/// In-process reference backend.
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn gp_fit_predict(&self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
        gp::GpSurrogate::default().fit_predict(x, y, cands)
    }

    fn rbf_fit_predict(
        &self,
        x: &Matrix,
        y: &[f64],
        ridge: f64,
        cands: &Matrix,
    ) -> rbf::RbfPrediction {
        // Escalate ridge on singular systems (duplicate evaluations).
        let mut r = ridge;
        for _ in 0..8 {
            if let Some(fit) = rbf::fit(x, y, r) {
                return fit.predict(cands);
            }
            r = if r == 0.0 { 1e-8 } else { r * 100.0 };
        }
        // Even the largest ridge failed (fully degenerate system, e.g.
        // non-finite inputs): degrade to the constant interpolant rather
        // than killing the whole search run.
        rbf::constant_prediction(x, y, cands)
    }

    fn gp_session(&self) -> Box<dyn GpSession + Send + '_> {
        Box::new(gp::IncrementalGp::default())
    }
}

/// Posterior prediction over a candidate set.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

/// A surrogate regression model with predictive uncertainty.
pub trait Surrogate {
    /// Fit on observations and predict at candidate points.
    ///
    /// `x`: n encoded configurations (one per row), `y`: n observed
    /// losses, `cands`: m encoded candidates (one per row). Returns
    /// mean/std of length m.
    fn fit_predict(&mut self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction;
}

/// Standard normal CDF via the same A&S 7.1.26 erf approximation baked
/// into the AOT artifact (keeps native and artifact paths numerically
/// aligned to ~1e-7).
pub fn norm_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf = sign * (1.0 - poly * (-x * x).exp());
    0.5 * (1.0 + erf)
}

pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Acquisition functions, oriented maximize-is-better for minimization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Acquisition {
    /// Expected improvement below `best_y`.
    Ei,
    /// Probability of improvement below `best_y`.
    Pi,
    /// Negative lower confidence bound, -(mean - kappa*std).
    Lcb { kappa: f64 },
}

impl Acquisition {
    pub fn score(&self, mean: f64, std: f64, best_y: f64) -> f64 {
        let std = std.max(1e-12);
        match *self {
            Acquisition::Ei => {
                let imp = best_y - mean;
                let z = imp / std;
                imp * norm_cdf(z) + std * norm_pdf(z)
            }
            Acquisition::Pi => norm_cdf((best_y - mean) / std),
            Acquisition::Lcb { kappa } => -(mean - kappa * std),
        }
    }

    /// Index of the best candidate under this acquisition, optionally
    /// excluding some candidates (e.g. already-evaluated ones).
    pub fn argmax(&self, pred: &Prediction, best_y: f64, excluded: &[bool]) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..pred.mean.len() {
            if *excluded.get(i).unwrap_or(&false) {
                continue;
            }
            let s = self.score(pred.mean[i], pred.std[i], best_y);
            if best.map(|(_, b)| s > b).unwrap_or(true) {
                best = Some((i, s));
            }
        }
        best.map(|(i, _)| i)
    }
}

/// Standardize y to zero mean / unit variance; returns (z, mean, std).
/// Degenerate inputs (constant y) get std = 1 to avoid division by zero.
pub fn standardize(y: &[f64]) -> (Vec<f64>, f64, f64) {
    let m = crate::util::stats::mean(y);
    let s = {
        let sd = crate::util::stats::stddev(y);
        if sd > 1e-12 { sd } else { 1.0 }
    };
    (y.iter().map(|v| (v - m) / s).collect(), m, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_cdf_reference_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.0) - 0.8413447).abs() < 1e-6);
        assert!((norm_cdf(-2.0) - 0.0227501).abs() < 1e-6);
        assert!(norm_cdf(6.0) > 0.999999);
    }

    #[test]
    fn ei_zero_when_hopeless_positive_when_promising() {
        let a = Acquisition::Ei;
        // mean far above best with tiny std: ~0 improvement expected.
        assert!(a.score(10.0, 0.01, 0.0) < 1e-9);
        // mean below best: at least the mean gap.
        assert!(a.score(-1.0, 0.1, 0.0) > 0.99);
        // More uncertainty, more EI (at equal mean).
        assert!(a.score(1.0, 2.0, 0.0) > a.score(1.0, 0.5, 0.0));
    }

    #[test]
    fn pi_monotone_in_mean() {
        let a = Acquisition::Pi;
        assert!(a.score(-1.0, 1.0, 0.0) > a.score(1.0, 1.0, 0.0));
    }

    #[test]
    fn lcb_prefers_low_mean_high_std() {
        let a = Acquisition::Lcb { kappa: 2.0 };
        assert!(a.score(1.0, 1.0, 0.0) > a.score(2.0, 1.0, 0.0));
        assert!(a.score(1.0, 2.0, 0.0) > a.score(1.0, 1.0, 0.0));
    }

    #[test]
    fn argmax_respects_exclusions() {
        let pred = Prediction { mean: vec![0.0, -5.0, 1.0], std: vec![0.1; 3] };
        let a = Acquisition::Ei;
        assert_eq!(a.argmax(&pred, 0.0, &[false, false, false]), Some(1));
        assert_eq!(a.argmax(&pred, 0.0, &[false, true, false]), Some(0));
        assert_eq!(a.argmax(&pred, 0.0, &[true, true, true]), None);
    }

    #[test]
    fn rbf_backend_escalates_ridge_on_duplicate_observations() {
        // Two identical points with conflicting targets make the saddle
        // system singular at ridge 0; the backend must escalate the ridge
        // and return a finite blend instead of failing.
        let x = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        let y = vec![1.0, 2.0];
        let p = NativeBackend.rbf_fit_predict(&x, &y, 0.0, &Matrix::from_rows(&[vec![0.5, 0.5]]));
        assert!(p.pred[0].is_finite());
        assert!((p.pred[0] - 1.5).abs() < 0.25, "blend {}", p.pred[0]);
    }

    #[test]
    fn rbf_backend_degrades_gracefully_when_no_ridge_helps() {
        // Non-finite coordinates poison every kernel entry: no ridge can
        // fix the system, and the backend must fall back to the constant
        // interpolant instead of panicking.
        let x = Matrix::from_rows(&[vec![f64::NAN, 0.5]; 3]);
        let y = vec![1.0, 2.0, 3.0];
        let p = NativeBackend.rbf_fit_predict(
            &x,
            &y,
            1e-6,
            &Matrix::from_rows(&[vec![0.1, 0.1], vec![0.9, 0.9]]),
        );
        assert_eq!(p.pred, vec![2.0, 2.0]);
    }

    #[test]
    fn default_gp_session_replays_full_fits() {
        // A session fed observations one by one must agree exactly with a
        // one-shot fit on the same data (it literally replays one).
        struct ReplayOnly;
        impl Backend for ReplayOnly {
            fn gp_fit_predict(&self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
                NativeBackend.gp_fit_predict(x, y, cands)
            }
            fn rbf_fit_predict(
                &self,
                x: &Matrix,
                y: &[f64],
                ridge: f64,
                cands: &Matrix,
            ) -> rbf::RbfPrediction {
                NativeBackend.rbf_fit_predict(x, y, ridge, cands)
            }
        }
        let backend = ReplayOnly;
        let mut sess = backend.gp_session();
        let rows = [vec![0.1, 0.2], vec![0.8, 0.3], vec![0.4, 0.9]];
        let x = Matrix::from_rows(&rows);
        let y = vec![1.0, 2.0, 1.5];
        for (xi, &yi) in rows.iter().zip(&y) {
            sess.observe(xi.clone(), yi);
        }
        assert_eq!(sess.n_obs(), 3);
        let cands = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.0, 1.0]]);
        let ps = sess.predict(&cands);
        let pf = backend.gp_fit_predict(&x, &y, &cands);
        for i in 0..cands.rows {
            assert_eq!(ps.mean[i], pf.mean[i]);
            assert_eq!(ps.std[i], pf.std[i]);
        }
        // Pinned predictions replay the same full fit.
        sess.pin_candidates(&cands);
        let pp = sess.predict_pinned();
        for i in 0..cands.rows {
            assert_eq!(pp.mean[i], pf.mean[i]);
            assert_eq!(pp.std[i], pf.std[i]);
        }
    }

    #[test]
    fn standardize_roundtrip() {
        let y = vec![3.0, 5.0, 7.0, 9.0];
        let (z, m, s) = standardize(&y);
        assert!((crate::util::stats::mean(&z)).abs() < 1e-12);
        for (zi, yi) in z.iter().zip(&y) {
            assert!((zi * s + m - yi).abs() < 1e-12);
        }
        // Constant input doesn't blow up.
        let (z2, _, s2) = standardize(&[4.0, 4.0]);
        assert_eq!(s2, 1.0);
        assert_eq!(z2, vec![0.0, 0.0]);
    }
}
