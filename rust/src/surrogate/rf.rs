//! Random-forest regressor (substrate).
//!
//! Serves two roles from the paper: the surrogate of the Bilal et al.
//! time-target scheme and SMAC-lite (predictive std = spread across
//! trees, the standard SMAC construction), and the PARIS-style predictive
//! baseline. CART regression trees, bootstrap sampling, random feature
//! subsets at each split, variance-reduction split criterion.

use super::{Prediction, Surrogate};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RfParams {
    pub n_trees: usize,
    pub min_leaf: usize,
    /// Features tried per split; 0 = ceil(d/3).
    pub mtry: usize,
    pub seed: u64,
    /// Bootstrap resampling (true for forest behaviour; false makes each
    /// tree see the full data — useful for tests).
    pub bootstrap: bool,
}

impl Default for RfParams {
    fn default() -> Self {
        RfParams { n_trees: 30, min_leaf: 2, mtry: 0, seed: 0x5EED, bootstrap: true }
    }
}

#[derive(Clone, Debug)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

pub struct RandomForest {
    pub params: RfParams,
    trees: Vec<Tree>,
}

impl RandomForest {
    pub fn new(params: RfParams) -> Self {
        RandomForest { params, trees: Vec::new() }
    }

    pub fn fit(&mut self, x: &Matrix, y: &[f64]) {
        assert_eq!(x.rows, y.len());
        assert!(x.rows > 0, "RF fit with no data");
        let d = x.cols;
        let mtry = if self.params.mtry == 0 { d.div_ceil(3) } else { self.params.mtry.min(d) };
        let mut rng = Rng::new(self.params.seed);
        self.trees = (0..self.params.n_trees)
            .map(|t| {
                let mut trng = rng.fork(t as u64);
                let idx: Vec<usize> = if self.params.bootstrap {
                    (0..x.rows).map(|_| trng.usize_below(x.rows)).collect()
                } else {
                    (0..x.rows).collect()
                };
                let mut tree = Tree { nodes: Vec::new() };
                build(&mut tree, x, y, idx, mtry, self.params.min_leaf, &mut trng);
                tree
            })
            .collect();
    }

    pub fn predict_one(&self, x: &[f64]) -> (f64, f64) {
        assert!(!self.trees.is_empty(), "predict before fit");
        let preds: Vec<f64> = self.trees.iter().map(|t| t.predict(x)).collect();
        let m = crate::util::stats::mean(&preds);
        let s = crate::util::stats::stddev(&preds);
        (m, s)
    }
}

fn build(
    tree: &mut Tree,
    x: &Matrix,
    y: &[f64],
    idx: Vec<usize>,
    mtry: usize,
    min_leaf: usize,
    rng: &mut Rng,
) -> usize {
    let mean: f64 = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
    // Stop: small node or pure targets.
    let sse: f64 = idx.iter().map(|&i| (y[i] - mean) * (y[i] - mean)).sum();
    if idx.len() < 2 * min_leaf || sse < 1e-12 {
        tree.nodes.push(Node::Leaf { value: mean });
        return tree.nodes.len() - 1;
    }

    let d = x.cols;
    let feats = rng.sample_indices(d, mtry.min(d));
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
    for &f in &feats {
        let mut vals: Vec<f64> = idx.iter().map(|&i| x[(i, f)]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        for w in vals.windows(2) {
            let thr = 0.5 * (w[0] + w[1]);
            let (mut nl, mut sl, mut nr, mut sr) = (0usize, 0.0, 0usize, 0.0);
            for &i in &idx {
                if x[(i, f)] <= thr {
                    nl += 1;
                    sl += y[i];
                } else {
                    nr += 1;
                    sr += y[i];
                }
            }
            if nl < min_leaf || nr < min_leaf {
                continue;
            }
            // Variance reduction == maximize sum of squared means weighted.
            let score = sl * sl / nl as f64 + sr * sr / nr as f64;
            if best.map(|(_, _, b)| score > b).unwrap_or(true) {
                best = Some((f, thr, score));
            }
        }
    }

    let Some((f, thr, _)) = best else {
        tree.nodes.push(Node::Leaf { value: mean });
        return tree.nodes.len() - 1;
    };

    let (li, ri): (Vec<usize>, Vec<usize>) = idx.iter().partition(|&&i| x[(i, f)] <= thr);
    let placeholder = tree.nodes.len();
    tree.nodes.push(Node::Leaf { value: mean }); // replaced below
    let left = build(tree, x, y, li, mtry, min_leaf, rng);
    let right = build(tree, x, y, ri, mtry, min_leaf, rng);
    tree.nodes[placeholder] = Node::Split { feature: f, threshold: thr, left, right };
    placeholder
}

impl Surrogate for RandomForest {
    fn fit_predict(&mut self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
        self.fit(x, y);
        let (mut mean, mut std) =
            (Vec::with_capacity(cands.rows), Vec::with_capacity(cands.rows));
        for j in 0..cands.rows {
            let (m, s) = self.predict_one(cands.row(j));
            mean.push(m);
            std.push(s);
        }
        Prediction { mean, std }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn step_data(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        // y = 10 if x0 > 0.5 else 0, plus small noise on x1 irrelevant dim.
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let y: Vec<f64> = rows.iter().map(|v| if v[0] > 0.5 { 10.0 } else { 0.0 }).collect();
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn learns_a_step_function() {
        let (x, y) = step_data(200, 1);
        let mut rf = RandomForest::new(RfParams::default());
        rf.fit(&x, &y);
        let (lo, _) = rf.predict_one(&[0.1, 0.5]);
        let (hi, _) = rf.predict_one(&[0.9, 0.5]);
        assert!(lo < 2.0, "lo {lo}");
        assert!(hi > 8.0, "hi {hi}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = step_data(100, 2);
        let mut a = RandomForest::new(RfParams::default());
        let mut b = RandomForest::new(RfParams::default());
        let pa = a.fit_predict(&x, &y, &x);
        let pb = b.fit_predict(&x, &y, &x);
        assert_eq!(pa.mean, pb.mean);
    }

    #[test]
    fn uncertainty_higher_near_boundary() {
        let (x, y) = step_data(300, 3);
        let mut rf = RandomForest::new(RfParams::default());
        rf.fit(&x, &y);
        let (_, s_boundary) = rf.predict_one(&[0.5, 0.5]);
        let (_, s_deep) = rf.predict_one(&[0.05, 0.5]);
        assert!(s_boundary >= s_deep, "{s_boundary} vs {s_deep}");
    }

    #[test]
    fn no_bootstrap_single_tree_fits_exactly() {
        // A single un-bootstrapped tree with min_leaf 1 memorizes the data.
        let x = Matrix::from_rows(&[vec![0.0], vec![0.25], vec![0.5], vec![0.75], vec![1.0]]);
        let y = vec![1.0, 5.0, 2.0, 8.0, 3.0];
        let mut rf = RandomForest::new(RfParams {
            n_trees: 1,
            min_leaf: 1,
            mtry: 1,
            seed: 4,
            bootstrap: false,
        });
        rf.fit(&x, &y);
        for (i, yi) in y.iter().enumerate() {
            assert_eq!(rf.predict_one(x.row(i)).0, *yi);
        }
    }

    #[test]
    fn handles_tiny_datasets() {
        let mut rf = RandomForest::new(RfParams::default());
        let p = rf.fit_predict(
            &Matrix::from_rows(&[vec![0.1], vec![0.9]]),
            &[1.0, 2.0],
            &Matrix::from_rows(&[vec![0.5]]),
        );
        assert!(p.mean[0] >= 1.0 && p.mean[0] <= 2.0);
    }

    #[test]
    fn property_predictions_within_target_range() {
        crate::testkit::check("rf predictions bounded by target range", 10, |g| {
            let n = g.usize_in(5, 40);
            let d = g.usize_in(1, 6);
            let x = Matrix::from_rows(
                &(0..n).map(|_| g.vec_f64(d, 0.0, 1.0)).collect::<Vec<Vec<f64>>>(),
            );
            let y = g.vec_f64(n, -5.0, 5.0);
            let mut rf = RandomForest::new(RfParams { n_trees: 10, ..Default::default() });
            let cands = Matrix::from_rows(
                &(0..10).map(|_| g.vec_f64(d, 0.0, 1.0)).collect::<Vec<Vec<f64>>>(),
            );
            let p = rf.fit_predict(&x, &y, &cands);
            let (lo, hi) =
                (crate::util::stats::min(&y) - 1e-9, crate::util::stats::max(&y) + 1e-9);
            for m in p.mean {
                assert!(m >= lo && m <= hi, "prediction {m} outside [{lo}, {hi}]");
            }
        });
    }
}
