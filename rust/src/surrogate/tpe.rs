//! Parzen categorical estimators — the building block of HyperOpt-lite.
//!
//! TPE splits observed configurations into a "good" set (best gamma
//! fraction) and a "bad" set, models each with smoothed categorical
//! densities l(x) and g(x), and proposes values maximizing l/g. The
//! hierarchical structure (provider first, then its conditional
//! parameters) is handled by the optimizer in `optimizers::hyperopt`;
//! this module provides the per-parameter density math.

use crate::util::rng::Rng;

/// Laplace-smoothed categorical distribution over `k` values.
#[derive(Clone, Debug)]
pub struct CatDensity {
    counts: Vec<f64>,
    total: f64,
}

impl CatDensity {
    /// Build from observed value indices with smoothing `alpha` (>0).
    pub fn from_observations(k: usize, obs: &[usize], alpha: f64) -> CatDensity {
        assert!(k > 0 && alpha > 0.0);
        let mut counts = vec![alpha; k];
        for &o in obs {
            assert!(o < k, "observation out of range");
            counts[o] += 1.0;
        }
        let total = counts.iter().sum();
        CatDensity { counts, total }
    }

    pub fn prob(&self, v: usize) -> f64 {
        self.counts[v] / self.total
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.weighted_index(&self.counts)
    }

    pub fn k(&self) -> usize {
        self.counts.len()
    }
}

/// The l/g density pair for one categorical parameter.
#[derive(Clone, Debug)]
pub struct TpePair {
    pub good: CatDensity,
    pub bad: CatDensity,
}

impl TpePair {
    pub fn new(k: usize, good_obs: &[usize], bad_obs: &[usize], alpha: f64) -> TpePair {
        TpePair {
            good: CatDensity::from_observations(k, good_obs, alpha),
            bad: CatDensity::from_observations(k, bad_obs, alpha),
        }
    }

    /// The TPE acquisition ratio l(v)/g(v) (higher = more promising).
    pub fn ratio(&self, v: usize) -> f64 {
        self.good.prob(v) / self.bad.prob(v)
    }

    /// Sample from the good density (candidate generation), as in TPE.
    pub fn sample_good(&self, rng: &mut Rng) -> usize {
        self.good.sample(rng)
    }
}

/// Split observation indices into (good, bad) by target value: the best
/// ceil(gamma * n) observations are "good". Returns indices into `ys`.
pub fn split_good_bad(ys: &[f64], gamma: f64) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..=1.0).contains(&gamma));
    let n = ys.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| ys[a].partial_cmp(&ys[b]).unwrap());
    let n_good = ((gamma * n as f64).ceil() as usize).clamp(1.min(n), n);
    let good = order[..n_good].to_vec();
    let bad = order[n_good..].to_vec();
    (good, bad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_normalizes() {
        let d = CatDensity::from_observations(3, &[0, 0, 1], 1.0);
        let total: f64 = (0..3).map(|v| d.prob(v)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert!(d.prob(0) > d.prob(1));
        assert!(d.prob(1) > d.prob(2));
    }

    #[test]
    fn smoothing_keeps_unseen_values_alive() {
        let d = CatDensity::from_observations(4, &[0; 50], 1.0);
        for v in 1..4 {
            assert!(d.prob(v) > 0.0);
        }
    }

    #[test]
    fn ratio_prefers_good_values() {
        // Value 0 dominates good observations, value 1 dominates bad.
        let p = TpePair::new(2, &[0, 0, 0, 0], &[1, 1, 1, 1], 0.5);
        assert!(p.ratio(0) > 1.0);
        assert!(p.ratio(1) < 1.0);
    }

    #[test]
    fn split_good_bad_orders_by_value() {
        let ys = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let (good, bad) = split_good_bad(&ys, 0.4);
        assert_eq!(good, vec![1, 3]); // values 1.0, 2.0
        assert_eq!(bad.len(), 3);
        assert!(good.iter().all(|&g| ys[g] <= bad.iter().map(|&b| ys[b]).fold(f64::INFINITY, f64::min)));
    }

    #[test]
    fn split_always_keeps_at_least_one_good() {
        let (good, bad) = split_good_bad(&[2.0, 1.0], 0.01);
        assert_eq!(good.len(), 1);
        assert_eq!(good[0], 1);
        assert_eq!(bad.len(), 1);
    }

    #[test]
    fn sampling_follows_density() {
        let d = CatDensity::from_observations(2, &[0; 99], 1.0);
        let mut rng = Rng::new(7);
        let zeros = (0..1000).filter(|_| d.sample(&mut rng) == 0).count();
        assert!(zeros > 900, "zeros {zeros}");
    }
}
