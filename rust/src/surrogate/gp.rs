//! Native Matern-5/2 Gaussian-process surrogate.
//!
//! Mirrors the math of the AOT artifact (`python/compile/model.py`)
//! exactly — masked padding aside — so the two backends are
//! interchangeable and cross-checked by the parity integration test:
//! same kernel, same jitter, same y-standardization convention, same
//! lengthscale grid selected by log marginal likelihood.
//!
//! ## Per-iteration cost (§Perf)
//!
//! The BO hot loop predicts over one fixed candidate grid (m points)
//! after every observation (n so far). Three generations of that cost:
//!
//! * full refit: 4 lengthscales × O(n³) Cholesky + m × O(n²) solves;
//! * incremental (PR 1-3): O(n²) factor append per observe, but still
//!   m × O(n²) triangular solves per predict inside `posterior_over`;
//! * **whitened cache (this file)**: each factor carries the whitened
//!   candidate matrix `V = L⁻¹ K(X, C)` and its per-column squared
//!   norms. An observe grows `V` by one row in O(n·m) via the appended
//!   factor's border row; a predict is one O(n²) solve `w = L⁻¹z` plus
//!   O(n·m) dot products — `mean_j = V_jᵀw`, `var_j = sv − ‖V_j‖²` —
//!   with **zero** per-candidate solves (asserted by the debug
//!   [`solve_lower_calls`](crate::linalg::solve_lower_calls) counter).
//!
//! For n > [`LML_SUBSET_MAX`] the lengthscale is additionally selected
//! on a strided observation subset (downsampled LML), so large-budget
//! tails pay the full-size solve once instead of once per grid point —
//! shared by the full-refit and incremental paths so both select the
//! same lengthscale and stay within the 1e-6 parity contract.

use super::{standardize, GpSession, Prediction, Surrogate};
use crate::linalg::{
    cholesky, cholesky_append, solve_lower, solve_lower_multi, solve_upper_t, Matrix,
};

/// Matches `JITTER` in python/compile/model.py.
pub const JITTER: f64 = 1e-5;

/// Lengthscale grid searched by marginal likelihood at each fit. The
/// encoded domain lives on the unit hypercube, so order-1 scales cover it.
pub const LS_GRID: [f64; 4] = [0.35, 0.7, 1.4, 2.8];

/// Above this many observations, lengthscale selection runs on a strided
/// subset of at most this size (downsampled LML); the winning lengthscale
/// then gets the single full-size fit/solve. Cuts the ×4 grid cost of
/// large-budget BO tails.
pub const LML_SUBSET_MAX: usize = 48;

#[derive(Clone, Debug)]
pub struct GpSurrogate {
    /// Observation noise variance (on standardized y).
    pub noise: f64,
    /// Signal variance (standardized y: 1.0).
    pub signal_var: f64,
    /// Chosen lengthscale from the last fit (for inspection/tests).
    pub last_lengthscale: f64,
}

impl Default for GpSurrogate {
    fn default() -> Self {
        GpSurrogate { noise: 1e-2, signal_var: 1.0, last_lengthscale: LS_GRID[1] }
    }
}

pub fn matern52(d2: f64, lengthscale: f64, signal_var: f64) -> f64 {
    let u = (5.0 * d2).sqrt() / lengthscale;
    signal_var * (1.0 + u + u * u / 3.0) * (-u).exp()
}

fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// All pairwise squared distances between the rows of `a` and the rows
/// of `b` (`out[(i, j)] = d²(a_i, b_j)`). Single definition shared by
/// the full-refit, unpinned, and pin-time paths — the bit-parity
/// contracts between them rest on this being one implementation, not
/// three hand-synchronized loops.
fn cross_d2(a: &Matrix, b: &Matrix) -> Matrix {
    // sqdist zips rows and would silently truncate to the shorter one;
    // mismatched encodings are caller bugs and must surface.
    debug_assert!(
        a.rows == 0 || b.rows == 0 || a.cols == b.cols,
        "encoded width mismatch: {} vs {}",
        a.cols,
        b.cols
    );
    let (n, m) = (a.rows, b.rows);
    let mut out = Matrix::zeros(n, m);
    for i in 0..n {
        let ai = a.row(i);
        for j in 0..m {
            out[(i, j)] = sqdist(ai, b.row(j));
        }
    }
    out
}

struct Fitted {
    l: Matrix,
    alpha: Vec<f64>,
    lml: f64,
}

/// The posterior loop shared by the unpinned GP prediction paths (full
/// refit, incremental `predict`): for each of `m` candidates,
/// `fill_kxc(j, &mut kxc)` writes K(X, cand_j) and the same mean /
/// variance math runs on top. The pinned path (`predict_pinned`) instead
/// reads the whitened cache and performs no per-candidate solves; the
/// parity tests pin the two within 1e-6.
fn posterior_over(
    l: &Matrix,
    alpha: &[f64],
    signal_var: f64,
    ym: f64,
    ys: f64,
    m: usize,
    mut fill_kxc: impl FnMut(usize, &mut [f64]),
) -> Prediction {
    let n = alpha.len();
    let mut mean = Vec::with_capacity(m);
    let mut std = Vec::with_capacity(m);
    let mut kxc = vec![0.0; n];
    for j in 0..m {
        fill_kxc(j, &mut kxc);
        let mu: f64 = kxc.iter().zip(alpha).map(|(a, b)| a * b).sum();
        let v = solve_lower(l, &kxc);
        let var = (signal_var - v.iter().map(|t| t * t).sum::<f64>()).max(1e-12);
        mean.push(mu * ys + ym);
        std.push(var.sqrt() * ys);
    }
    Prediction { mean, std }
}

/// Kernel build + factorization from a precomputed squared-distance
/// matrix — the shared first half of [`fit_from_d2`], also used to
/// (re)build cached subset factors so cached and from-scratch selection
/// factor the identical matrix.
fn kernel_chol_from_d2(d2: &Matrix, ls: f64, sv: f64, noise: f64) -> Option<Matrix> {
    let n = d2.rows;
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(d2[(i, j)], ls, sv);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += noise + JITTER;
    }
    cholesky(&k)
}

/// Log marginal likelihood of standardized targets under a factored
/// kernel, returning `(w = L⁻¹z, alpha = K⁻¹z, lml)` — the shared
/// second half of [`fit_from_d2`]. Every model-selection path (full
/// refit, incremental small-n, cached subset) scores lengthscales
/// through this one function, so near-tie LMLs cannot resolve
/// differently across paths.
fn lml_from_chol(l: &Matrix, z: &[f64]) -> (Vec<f64>, Vec<f64>, f64) {
    let n = z.len();
    let w = solve_lower(l, z);
    let alpha = solve_upper_t(l, &w);
    let quad: f64 = z.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let logdet: f64 = (0..n).map(|i| l[(i, i)].ln()).sum();
    let lml = -0.5 * quad - logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    (w, alpha, lml)
}

/// Fit from a precomputed observation-observation squared-distance matrix
/// (the distance computation is shared across the lengthscale grid — the
/// §Perf L3 optimization, ~4x fewer O(n^2 d) passes per BO iteration).
fn fit_from_d2(d2: &Matrix, z: &[f64], ls: f64, sv: f64, noise: f64) -> Option<Fitted> {
    let l = kernel_chol_from_d2(d2, ls, sv, noise)?;
    let (_, alpha, lml) = lml_from_chol(&l, z);
    Some(Fitted { l, alpha, lml })
}

/// The strided observation subset used for downsampled-LML selection at
/// a given n: a pure function of n, so the indices (and everything built
/// from them) are reusable across predicts until n grows past the next
/// membership change.
fn subset_indices(n: usize) -> (usize, Vec<usize>) {
    let stride = n.div_ceil(LML_SUBSET_MAX);
    (stride, (0..n).step_by(stride).collect())
}

/// Grow cached per-lengthscale subset factors in place to cover
/// `new_idx`, whose prefix must be the members already factored (true
/// whenever the stride is unchanged: `subset_indices` is a pure strided
/// function of n, so a larger n at the same stride only appends
/// members). Each new member costs one O(s²) `cholesky_append` border
/// per lengthscale instead of the O(s³) refactor. Returns false — with
/// the cache left for the caller to rebuild wholesale — if any append
/// loses positive definiteness or a cached factor is already missing.
fn grow_subset_factors(
    s: &mut SubsetSelect,
    new_idx: &[usize],
    x: &Matrix,
    sv: f64,
    noise: f64,
) -> bool {
    debug_assert_eq!(&new_idx[..s.idx.len()], &s.idx[..]);
    for step in s.idx.len()..new_idx.len() {
        let member = new_idx[step];
        // One distance row against the current members, shared by the
        // whole lengthscale grid (same sharing as `subset_d2`).
        let d2row: Vec<f64> =
            new_idx[..step].iter().map(|&i| sqdist(x.row(member), x.row(i))).collect();
        for (li, &ls) in LS_GRID.iter().enumerate() {
            let Some(l) = s.chol[li].as_ref() else { return false };
            let k: Vec<f64> = d2row.iter().map(|&v| matern52(v, ls, sv)).collect();
            let diag = matern52(0.0, ls, sv) + noise + JITTER;
            match cholesky_append(l, &k, diag) {
                Some(grown) => s.chol[li] = Some(grown),
                None => return false,
            }
        }
        s.idx.push(member);
    }
    true
}

/// Pairwise squared distances between the subset rows of `x`.
fn subset_d2(x: &Matrix, idx: &[usize]) -> Matrix {
    let s = idx.len();
    let mut d2 = Matrix::zeros(s, s);
    for (a, &i) in idx.iter().enumerate() {
        for (b, &j) in idx.iter().enumerate().take(a) {
            let v = sqdist(x.row(i), x.row(j));
            d2[(a, b)] = v;
            d2[(b, a)] = v;
        }
    }
    d2
}

/// Downsampled-LML lengthscale selection: rank the grid on a strided
/// subset of at most [`LML_SUBSET_MAX`] observations. Shared by the
/// full-refit, incremental, *and* PJRT-artifact paths — all compute the
/// subset kernel from the same inputs with the same ops, so they select
/// identically and the native/artifact interchangeability contract
/// survives the n > 48 regime. Returns None when no subset fit
/// succeeded (callers fall back to full-grid selection).
///
/// This raw-rows entry point serves the PJRT artifact backend (feature
/// `pjrt`, which has no precomputed distance matrix); the native paths
/// use the gather/cached variants below.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) fn select_ls_downsampled(x: &Matrix, z: &[f64], sv: f64, noise: f64) -> Option<usize> {
    let (_, idx) = subset_indices(x.rows);
    let d2 = subset_d2(x, &idx);
    rank_ls_on_subset(&d2, &idx, z, sv, noise)
}

/// As [`select_ls_downsampled`], but gathering the subset entries from a
/// precomputed full n×n distance matrix instead of recomputing them —
/// identical f64s, so the two entry points rank identically.
fn select_ls_downsampled_from_d2(d2xx: &Matrix, z: &[f64], sv: f64, noise: f64) -> Option<usize> {
    let (_, idx) = subset_indices(d2xx.rows);
    let s = idx.len();
    let mut d2 = Matrix::zeros(s, s);
    for (a, &i) in idx.iter().enumerate() {
        for (b, &j) in idx.iter().enumerate().take(a) {
            let v = d2xx[(i, j)];
            d2[(a, b)] = v;
            d2[(b, a)] = v;
        }
    }
    rank_ls_on_subset(&d2, &idx, z, sv, noise)
}

/// The shared ranking tail of the downsampled-selection entry points.
fn rank_ls_on_subset(d2: &Matrix, idx: &[usize], z: &[f64], sv: f64, noise: f64) -> Option<usize> {
    let zs: Vec<f64> = idx.iter().map(|&i| z[i]).collect();
    let mut best: Option<(usize, f64)> = None;
    for (li, &ls) in LS_GRID.iter().enumerate() {
        if let Some(f) = fit_from_d2(d2, &zs, ls, sv, noise) {
            if best.map(|(_, b)| f.lml > b).unwrap_or(true) {
                best = Some((li, f.lml));
            }
        }
    }
    best.map(|(li, _)| li)
}

impl Surrogate for GpSurrogate {
    fn fit_predict(&mut self, x: &Matrix, y: &[f64], cands: &Matrix) -> Prediction {
        assert!(x.rows > 0, "GP fit with no observations");
        assert_eq!(x.rows, y.len());
        let (z, ym, ys) = standardize(y);
        let n = x.rows;
        let m = cands.rows;

        // Shared distance matrices (reused by all lengthscale fits).
        let mut d2xx = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = sqdist(x.row(i), x.row(j));
                d2xx[(i, j)] = v;
                d2xx[(j, i)] = v;
            }
        }
        let d2xc = cross_d2(x, cands);

        // Model selection: pick the lengthscale maximizing the marginal
        // likelihood (the artifact path does the same via repeated
        // executions with different hyp vectors). Past LML_SUBSET_MAX
        // the grid is ranked on a strided subset and only the winner
        // pays the full O(n³) fit; a degenerate subset falls back to the
        // full-grid loop.
        let mut best: Option<(usize, Fitted)> = None;
        if n > LML_SUBSET_MAX {
            if let Some(li) = select_ls_downsampled_from_d2(&d2xx, &z, self.signal_var, self.noise)
            {
                let ls = LS_GRID[li];
                if let Some(f) = fit_from_d2(&d2xx, &z, ls, self.signal_var, self.noise) {
                    best = Some((li, f));
                }
            }
        }
        if best.is_none() {
            for (li, &ls) in LS_GRID.iter().enumerate() {
                if let Some(f) = fit_from_d2(&d2xx, &z, ls, self.signal_var, self.noise) {
                    if best.as_ref().map(|(_, b)| f.lml > b.lml).unwrap_or(true) {
                        best = Some((li, f));
                    }
                }
            }
        }
        let (li, fitted) =
            best.expect("GP fit failed for every lengthscale (should be impossible with jitter)");
        let ls = LS_GRID[li];
        self.last_lengthscale = ls;

        let sv = self.signal_var;
        posterior_over(&fitted.l, &fitted.alpha, sv, ym, ys, m, |j, kxc| {
            for i in 0..n {
                kxc[i] = matern52(d2xc[(i, j)], ls, sv);
            }
        })
    }
}

// ---------------------------------------------------------------------------
// Incremental session: O(n²) per added observation instead of O(n³).

/// Build the full Cholesky factor of K(X,X) + (noise + jitter) I for one
/// lengthscale — the reference path the incremental appends must match.
fn full_chol(x: &Matrix, ls: f64, sv: f64, noise: f64) -> Option<Matrix> {
    let n = x.rows;
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(sqdist(x.row(i), x.row(j)), ls, sv);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += noise + JITTER;
    }
    cholesky(&k)
}

/// Whitened candidate state for one lengthscale: `v = L⁻¹ K(X, C)` over
/// the pinned candidate set, plus the running per-column squared norms
/// feeding the O(1)-per-candidate posterior variance.
struct Whitened {
    /// n×m; row i is the whitening of observation i against all pinned
    /// candidates. Grown one row per observe (O(n·m)).
    v: Matrix,
    /// colsq[j] = ‖V_j‖² accumulated in row order, so the incremental
    /// update (`+= v_nj²`) is bit-identical to a wholesale rebuild.
    colsq: Vec<f64>,
}

/// Stateful Matern-5/2 GP session with **incremental** Cholesky updates
/// and a **whitened candidate cache** for the pinned BO grid.
///
/// The kernel matrix depends only on the inputs, so one factorization is
/// cached per [`LS_GRID`] entry and grown by a rank-1 border
/// ([`cholesky_append`]) per new observation — O(n²) instead of the
/// O(n³) full refit every BO iteration pays otherwise. On top of each
/// factor the session keeps the whitened pinned-candidate matrix
/// `V = L⁻¹ K(X, C)` (see [`Whitened`]): the border row of an appended
/// factor extends `V` by one row in O(n·m) from the cached kernel rows,
/// and `predict_pinned` then needs one O(n²) solve `w = L⁻¹z` plus
/// O(n·m) dots — no per-candidate triangular solves at all. Everything
/// that depends on y (standardization, the log marginal likelihood
/// driving lengthscale selection) is recomputed per predict from the
/// cached factor, so model selection is semantically identical to
/// [`GpSurrogate::fit_predict`] — including the downsampled-LML rule
/// past [`LML_SUBSET_MAX`] observations; the parity tests below assert
/// agreement within 1e-6.
pub struct IncrementalGp {
    /// Observation noise variance (on standardized y).
    pub noise: f64,
    /// Signal variance (standardized y: 1.0).
    pub signal_var: f64,
    /// Chosen lengthscale from the last predict (for inspection/tests).
    pub last_lengthscale: f64,
    /// n×d observed inputs (width adopted from the first observation).
    x: Matrix,
    y: Vec<f64>,
    /// One cached factor per lengthscale-grid point; None when the
    /// bordered matrix lost positive definiteness and the rebuild also
    /// failed (that lengthscale then sits out model selection, exactly
    /// like a failed `fit_from_d2`).
    chol: Vec<Option<Matrix>>,
    /// Pinned candidate set, m×d (the BO loop predicts over one fixed
    /// grid); rows == 0 means nothing is pinned.
    pinned: Matrix,
    /// pinned_k[li][(i, j)] = matern52(d²(x_i, pinned_j), LS_GRID[li]):
    /// cached kernel rows against the pinned grid. Only the appended row
    /// (one O(m·d) distance pass + O(m) kernel map) is computed per
    /// observation; rebuilt wholesale when the candidate set is
    /// (re)pinned. Source data for whitened rebuilds.
    pinned_k: Vec<Matrix>,
    /// Whitened candidate matrix + column norms per lengthscale; None
    /// exactly when `chol` is None or nothing is pinned. Appends ride
    /// the factor appends; factor rebuilds trigger wholesale rewhitening
    /// (bit-identical to the appended path by construction).
    whitened: Vec<Option<Whitened>>,
    /// Cached downsampled-LML state for the n > [`LML_SUBSET_MAX`]
    /// regime. The subset is a pure function of n over the immutable
    /// observation prefix, so at a fixed stride new members only extend
    /// it: every `stride`-th observe grows each per-lengthscale factor
    /// by an O(s²) `cholesky_append` border, and only a stride jump (or
    /// a lost pivot) pays a from-scratch O(s³) refactor — a
    /// steady-state predict ranks the grid with one O(s²) solve pair
    /// per lengthscale.
    subset: Option<SubsetSelect>,
}

/// See [`IncrementalGp::subset`].
struct SubsetSelect {
    stride: usize,
    idx: Vec<usize>,
    /// One subset factor per [`LS_GRID`] entry (None = that subset fit
    /// lost positive definiteness, exactly like a failed `fit_from_d2`).
    chol: Vec<Option<Matrix>>,
}

impl Default for IncrementalGp {
    fn default() -> Self {
        let base = GpSurrogate::default();
        IncrementalGp {
            noise: base.noise,
            signal_var: base.signal_var,
            last_lengthscale: base.last_lengthscale,
            x: Matrix::zeros(0, 0),
            y: Vec::new(),
            chol: vec![None; LS_GRID.len()],
            pinned: Matrix::zeros(0, 0),
            pinned_k: (0..LS_GRID.len()).map(|_| Matrix::zeros(0, 0)).collect(),
            whitened: (0..LS_GRID.len()).map(|_| None).collect(),
            subset: None,
        }
    }
}

impl IncrementalGp {
    /// Model selection over the cached factors: the grid index maximizing
    /// the log marginal likelihood on standardized targets, plus the
    /// whitened target vector `w = L⁻¹z` for the winner (`wᵀw` is the
    /// LML quadratic term, and the pinned posterior mean is `V_jᵀw`) and
    /// the winner's `alpha = K⁻¹z` when selection already computed it
    /// (the unpinned posterior consumes it; None on the subset path,
    /// which never back-solves at full size). Past [`LML_SUBSET_MAX`]
    /// observations the ranking runs on the cached strided-subset
    /// factors — one full-size solve instead of four, plus bounded
    /// O(s²) subset work.
    fn select_model(&mut self, z: &[f64]) -> (usize, Vec<f64>, Option<Vec<f64>>) {
        let n = z.len();
        if n > LML_SUBSET_MAX {
            if let Some(li) = self.select_ls_subset_cached(z) {
                if let Some(l) = &self.chol[li] {
                    return (li, solve_lower(l, z), None);
                }
            }
        }
        let mut best: Option<(usize, Vec<f64>, Vec<f64>, f64)> = None;
        for li in 0..LS_GRID.len() {
            let Some(l) = &self.chol[li] else { continue };
            let (w, alpha, lml) = lml_from_chol(l, z);
            if best.as_ref().map(|(_, _, _, b)| lml > *b).unwrap_or(true) {
                best = Some((li, w, alpha, lml));
            }
        }
        let (li, w, alpha, _) =
            best.expect("GP fit failed for every lengthscale (should be impossible with jitter)");
        (li, w, Some(alpha))
    }

    /// Downsampled-LML ranking against the cached subset factors. At an
    /// unchanged stride, new subset members only *extend* the stored
    /// index prefix, so each cached factor grows by O(s²)
    /// `cholesky_append` borders ([`grow_subset_factors`]); a stride
    /// jump — or an append that loses positive definiteness — rebuilds
    /// everything from scratch. The kernel entries, factors, and LML
    /// formula are the ones `select_ls_downsampled` computes (shared
    /// `subset_indices`/`subset_d2`/`kernel_chol_from_d2`/
    /// `lml_from_chol`); appended factors agree with refactored ones to
    /// rounding, and the grid's LML gaps dwarf that — the parity suite
    /// pins exact lengthscale agreement with the full-refit reference
    /// through both regimes.
    fn select_ls_subset_cached(&mut self, z: &[f64]) -> Option<usize> {
        let (stride, idx) = subset_indices(self.x.rows);
        let fresh = match self.subset.as_mut() {
            Some(s) if s.stride == stride && s.idx.len() == idx.len() => true,
            Some(s) if s.stride == stride && s.idx.len() < idx.len() => {
                grow_subset_factors(s, &idx, &self.x, self.signal_var, self.noise)
            }
            _ => false,
        };
        if !fresh {
            let d2 = subset_d2(&self.x, &idx);
            let chol = LS_GRID
                .iter()
                .map(|&ls| kernel_chol_from_d2(&d2, ls, self.signal_var, self.noise))
                .collect();
            self.subset = Some(SubsetSelect { stride, idx, chol });
        }
        let sub = self.subset.as_ref().unwrap();
        let zs: Vec<f64> = sub.idx.iter().map(|&i| z[i]).collect();
        let mut best: Option<(usize, f64)> = None;
        for (li, l) in sub.chol.iter().enumerate() {
            let Some(l) = l else { continue };
            let (_, _, lml) = lml_from_chol(l, &zs);
            if best.map(|(_, b)| lml > b).unwrap_or(true) {
                best = Some((li, lml));
            }
        }
        best.map(|(li, _)| li)
    }

    /// Posterior from precomputed observation-candidate squared
    /// distances (`d2[(i, j)] = d²(x_i, cand_j)`, `m` candidates).
    ///
    /// Candidate-rich calls (`m > n`) whiten the kernel block on the
    /// fly: one `solve_lower_multi` over the n×m block replaces m
    /// per-candidate `solve_lower` back-substitutions (and `alpha` is
    /// never materialized) — the `predict_pinned` economics without
    /// requiring the caller to pin its ad-hoc candidate set first.
    /// Candidate-poor calls keep the per-candidate loop, where
    /// building the block would cost more than it saves. Means agree
    /// with the per-candidate path to summation order (the parity test
    /// pins 1e-6); stds run the identical per-column math.
    fn posterior_from_d2(&mut self, m: usize, d2: &Matrix) -> Prediction {
        assert!(self.x.rows > 0, "GP predict with no observations");
        let (z, ym, ys) = standardize(&self.y);
        let (li, w, alpha) = self.select_model(&z);
        let ls = LS_GRID[li];
        self.last_lengthscale = ls;
        let l = self.chol[li].as_ref().unwrap();

        let sv = self.signal_var;
        let n = self.x.rows;
        if m > n {
            let mut k = Matrix::zeros(n, m);
            for i in 0..n {
                for j in 0..m {
                    k[(i, j)] = matern52(d2[(i, j)], ls, sv);
                }
            }
            let wh = Self::whiten(l, &k);
            // mean_j = V_jᵀ w (≡ kxcᵀ K⁻¹ z), accumulated row-major —
            // the same contiguous scan predict_pinned runs.
            let mut mean = vec![0.0; m];
            for i in 0..n {
                let wi = w[i];
                for (acc, &vij) in mean.iter_mut().zip(wh.v.row(i)) {
                    *acc += vij * wi;
                }
            }
            let std: Vec<f64> =
                wh.colsq.iter().map(|&sq| (sv - sq).max(1e-12).sqrt() * ys).collect();
            for mj in mean.iter_mut() {
                *mj = *mj * ys + ym;
            }
            return Prediction { mean, std };
        }

        let alpha = alpha.unwrap_or_else(|| solve_upper_t(l, &w));
        posterior_over(l, &alpha, sv, ym, ys, m, |j, kxc| {
            for i in 0..n {
                kxc[i] = matern52(d2[(i, j)], ls, sv);
            }
        })
    }

    /// Wholesale whitening of the cached kernel rows against a factor:
    /// `V = L⁻¹ K`, column norms accumulated in row order (the same
    /// per-element operation order the incremental append performs, so
    /// rebuild and append never drift apart bitwise).
    fn whiten(l: &Matrix, k: &Matrix) -> Whitened {
        debug_assert_eq!(l.rows, k.rows);
        let v = solve_lower_multi(l, k);
        let mut colsq = vec![0.0; k.cols];
        for i in 0..v.rows {
            for (sq, &vij) in colsq.iter_mut().zip(v.row(i)) {
                *sq += vij * vij;
            }
        }
        Whitened { v, colsq }
    }
}

impl GpSession for IncrementalGp {
    fn observe(&mut self, x_new: Vec<f64>, y_new: f64) {
        let n_prev = self.x.rows;
        let m = self.pinned.rows;

        // Grow the pinned kernel-row caches by one row each before
        // touching the factors: the whitened append below consumes the
        // fresh kernel row. Every earlier row is unchanged by
        // construction (the kernel depends only on inputs and the fixed
        // grid), and the distance row is shared across the lengthscale
        // grid.
        if m > 0 {
            debug_assert_eq!(x_new.len(), self.pinned.cols, "encoded width mismatch");
            let d2_row: Vec<f64> = (0..m).map(|j| sqdist(&x_new, self.pinned.row(j))).collect();
            for (li, &ls) in LS_GRID.iter().enumerate() {
                let k_row: Vec<f64> =
                    d2_row.iter().map(|&d2| matern52(d2, ls, self.signal_var)).collect();
                self.pinned_k[li].push_row(&k_row);
            }
        }

        let diag = self.signal_var + self.noise + JITTER;
        // Distances are lengthscale-independent: one pass over the prior
        // observations serves all LS_GRID appends.
        let d2_new: Vec<f64> =
            (0..n_prev).map(|i| sqdist(self.x.row(i), &x_new)).collect();
        for (li, &ls) in LS_GRID.iter().enumerate() {
            let appended = match &self.chol[li] {
                Some(l) if l.rows == n_prev => {
                    let k_new: Vec<f64> = d2_new
                        .iter()
                        .map(|&d2| matern52(d2, ls, self.signal_var))
                        .collect();
                    cholesky_append(l, &k_new, diag)
                }
                _ => None,
            };
            match appended {
                Some(l_new) => {
                    // O(n·m) whitened growth: forward-substitute the
                    // appended kernel row through the border row of the
                    // new factor — the exact row `solve_lower_multi`
                    // would produce, so appends and rebuilds agree
                    // bitwise.
                    match (m > 0, &mut self.whitened[li]) {
                        (true, Some(wh)) if wh.v.rows == n_prev => {
                            let border = l_new.row(n_prev);
                            let mut v_row = self.pinned_k[li].row(n_prev).to_vec();
                            for i in 0..n_prev {
                                let c = border[i];
                                for (vj, &pj) in v_row.iter_mut().zip(wh.v.row(i)) {
                                    *vj -= c * pj;
                                }
                            }
                            let pivot = border[n_prev];
                            for (vj, sq) in v_row.iter_mut().zip(wh.colsq.iter_mut()) {
                                *vj /= pivot;
                                *sq += *vj * *vj;
                            }
                            wh.v.push_row(&v_row);
                        }
                        (true, slot) => {
                            *slot = Some(Self::whiten(&l_new, &self.pinned_k[li]));
                        }
                        (false, slot) => *slot = None,
                    }
                    self.chol[li] = Some(l_new);
                }
                None => {
                    self.chol[li] =
                        full_chol_appended(&self.x, &x_new, ls, self.signal_var, self.noise);
                    self.whitened[li] = match (&self.chol[li], m > 0) {
                        (Some(l), true) => Some(Self::whiten(l, &self.pinned_k[li])),
                        _ => None,
                    };
                }
            }
        }

        self.x.push_row(&x_new);
        self.y.push(y_new);
    }

    fn predict(&mut self, cands: &Matrix) -> Prediction {
        let d2 = cross_d2(&self.x, cands);
        self.posterior_from_d2(cands.rows, &d2)
    }

    fn pin_candidates(&mut self, cands: &Matrix) {
        self.pinned = cands.clone();
        let n = self.x.rows;
        let m = cands.rows;
        let d2 = cross_d2(&self.x, cands);
        // Invalidate and rebuild the kernel rows and whitened matrices
        // for the new grid.
        self.pinned_k = LS_GRID
            .iter()
            .map(|&ls| {
                let mut k = Matrix::zeros(n, m);
                for i in 0..n {
                    for j in 0..m {
                        k[(i, j)] = matern52(d2[(i, j)], ls, self.signal_var);
                    }
                }
                k
            })
            .collect();
        self.whitened = (0..LS_GRID.len())
            .map(|li| self.chol[li].as_ref().map(|l| Self::whiten(l, &self.pinned_k[li])))
            .collect();
    }

    fn predict_pinned(&mut self) -> Prediction {
        assert!(self.pinned.rows > 0, "predict_pinned without pinned candidates");
        assert!(self.x.rows > 0, "GP predict with no observations");
        let (n, m) = (self.x.rows, self.pinned.rows);
        let (z, ym, ys) = standardize(&self.y);
        let (li, w, _) = self.select_model(&z);
        self.last_lengthscale = LS_GRID[li];
        let wh = self.whitened[li]
            .as_ref()
            .expect("whitened cache is maintained alongside every live factor");
        debug_assert_eq!(wh.v.rows, n);
        // mean_j = V_jᵀ w (≡ kxcᵀ K⁻¹ z), accumulated row-major over the
        // whitened matrix — contiguous scans, no per-candidate solve.
        let mut mean = vec![0.0; m];
        for i in 0..n {
            let wi = w[i];
            for (acc, &vij) in mean.iter_mut().zip(wh.v.row(i)) {
                *acc += vij * wi;
            }
        }
        let sv = self.signal_var;
        let std: Vec<f64> = wh.colsq.iter().map(|&sq| (sv - sq).max(1e-12).sqrt() * ys).collect();
        for mj in mean.iter_mut() {
            *mj = *mj * ys + ym;
        }
        Prediction { mean, std }
    }

    fn n_obs(&self) -> usize {
        self.y.len()
    }
}

/// Full factor over the already-stored rows plus the not-yet-pushed new
/// one (the rebuild path of `observe`, which runs before `x` grows).
fn full_chol_appended(x: &Matrix, x_new: &[f64], ls: f64, sv: f64, noise: f64) -> Option<Matrix> {
    let mut grown = x.clone();
    grown.push_row(x_new);
    full_chol(&grown, ls, sv, noise)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> =
            rows.iter().map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0).collect();
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn interpolates_at_training_points() {
        let (x, y) = toy_data(20, 4, 1);
        let mut gp = GpSurrogate { noise: 1e-6, ..Default::default() };
        let pred = gp.fit_predict(&x, &y, &x);
        for (m, yv) in pred.mean.iter().zip(&y) {
            assert!((m - yv).abs() < 0.05, "{m} vs {yv}");
        }
        // Tiny predictive std at observed points.
        assert!(pred.std.iter().all(|&s| s < 0.2));
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let (x, y) = toy_data(10, 3, 2);
        let mut gp = GpSurrogate::default();
        let far = Matrix::from_rows(&[vec![10.0; 3]]);
        let near = Matrix::from_rows(&[x.row(0).to_vec()]);
        let p_far = gp.fit_predict(&x, &y, &far);
        let p_near = gp.fit_predict(&x, &y, &near);
        assert!(p_far.std[0] > 3.0 * p_near.std[0]);
    }

    #[test]
    fn far_prediction_reverts_to_prior_mean() {
        let (x, y) = toy_data(15, 3, 3);
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &Matrix::from_rows(&[vec![50.0; 3]]));
        let ym = crate::util::stats::mean(&y);
        assert!((p.mean[0] - ym).abs() < 0.5);
    }

    #[test]
    fn lengthscale_selection_adapts() {
        // Smooth function -> long lengthscale beats the shortest one.
        let mut rng = Rng::new(4);
        let rows: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.f64()]).collect();
        let y: Vec<f64> = rows.iter().map(|v| v[0] * 2.0 + 1.0).collect();
        let x = Matrix::from_rows(&rows);
        let mut gp = GpSurrogate::default();
        gp.fit_predict(&x, &y, &x);
        assert!(gp.last_lengthscale > LS_GRID[0]);
    }

    #[test]
    fn matern_kernel_values() {
        assert!((matern52(0.0, 1.0, 2.0) - 2.0).abs() < 1e-12);
        let u = 5.0f64.sqrt();
        let want = (1.0 + u + u * u / 3.0) * (-u).exp();
        assert!((matern52(1.0, 1.0, 1.0) - want).abs() < 1e-12);
    }

    #[test]
    fn handles_single_observation() {
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(
            &Matrix::from_rows(&[vec![0.5, 0.5]]),
            &[3.0],
            &Matrix::from_rows(&[vec![0.5, 0.5], vec![0.9, 0.1]]),
        );
        assert_eq!(p.mean.len(), 2);
        assert!(p.mean.iter().all(|m| m.is_finite()));
    }

    /// Randomized incremental/full parity suite: a session grown one
    /// observation at a time must agree with the full-refit reference
    /// within 1e-6 at every step — on both the unpinned path and the
    /// whitened pinned path — and select the same lengthscale.
    #[test]
    fn incremental_matches_full_refit_within_1e6() {
        crate::testkit::check("incremental GP parity", 12, |g| {
            let d = g.usize_in(1, 6);
            let n = g.usize_in(2, 24);
            let m = g.usize_in(1, 12);
            let rng = g.rng();
            let xrows: Vec<Vec<f64>> =
                (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
            let y: Vec<f64> = xrows
                .iter()
                .map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0 + 0.05 * rng.normal())
                .collect();
            let cands = Matrix::from_rows(
                &(0..m)
                    .map(|_| (0..d).map(|_| rng.f64() * 1.5).collect())
                    .collect::<Vec<Vec<f64>>>(),
            );

            let mut session = IncrementalGp::default();
            let mut whitened = IncrementalGp::default();
            whitened.pin_candidates(&cands);
            let mut reference = GpSurrogate::default();
            for i in 0..n {
                session.observe(xrows[i].clone(), y[i]);
                whitened.observe(xrows[i].clone(), y[i]);
                // Check parity at a few prefix lengths, always at the end.
                if i + 1 == n || i % 5 == 4 {
                    let ps = session.predict(&cands);
                    let pw = whitened.predict_pinned();
                    let pf =
                        reference.fit_predict(&Matrix::from_rows(&xrows[..=i]), &y[..=i], &cands);
                    assert_eq!(session.last_lengthscale, reference.last_lengthscale);
                    assert_eq!(whitened.last_lengthscale, reference.last_lengthscale);
                    for j in 0..m {
                        for (path, p) in [("unpinned", &ps), ("whitened", &pw)] {
                            assert!(
                                (p.mean[j] - pf.mean[j]).abs() < 1e-6,
                                "{path} n={} cand {j}: mean {} vs {}",
                                i + 1,
                                p.mean[j],
                                pf.mean[j]
                            );
                            assert!(
                                (p.std[j] - pf.std[j]).abs() < 1e-6,
                                "{path} n={} cand {j}: std {} vs {}",
                                i + 1,
                                p.std[j],
                                pf.std[j]
                            );
                        }
                    }
                }
            }
        });
    }

    /// Parity at the paper's largest budget: 88 successive appends on one
    /// factor must not drift past 1e-6 from the full refit. This also
    /// pins the n > LML_SUBSET_MAX downsampled-LML regime: past 48
    /// observations both paths rank lengthscales on the same strided
    /// subset and must keep choosing identically.
    #[test]
    fn incremental_parity_at_budget_scale() {
        let mut rng = Rng::new(88);
        let d = 5;
        let n = 88;
        let xrows: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = xrows
            .iter()
            .map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0 + 0.05 * rng.normal())
            .collect();
        let cands = Matrix::from_rows(
            &(0..8).map(|_| (0..d).map(|_| rng.f64() * 1.5).collect()).collect::<Vec<Vec<f64>>>(),
        );
        let mut session = IncrementalGp::default();
        let mut whitened = IncrementalGp::default();
        whitened.pin_candidates(&cands);
        let mut reference = GpSurrogate::default();
        for i in 0..n {
            session.observe(xrows[i].clone(), y[i]);
            whitened.observe(xrows[i].clone(), y[i]);
            if [24, 48, 60, 88].contains(&(i + 1)) {
                let ps = session.predict(&cands);
                let pw = whitened.predict_pinned();
                let pf = reference.fit_predict(&Matrix::from_rows(&xrows[..=i]), &y[..=i], &cands);
                assert_eq!(session.last_lengthscale, reference.last_lengthscale);
                assert_eq!(whitened.last_lengthscale, reference.last_lengthscale);
                for j in 0..cands.rows {
                    for (path, p) in [("unpinned", &ps), ("whitened", &pw)] {
                        assert!(
                            (p.mean[j] - pf.mean[j]).abs() < 1e-6
                                && (p.std[j] - pf.std[j]).abs() < 1e-6,
                            "{path} n={}: cand {j} mean {} vs {} / std {} vs {}",
                            i + 1,
                            p.mean[j],
                            pf.mean[j],
                            p.std[j],
                            pf.std[j]
                        );
                    }
                }
            }
        }
    }

    /// The cached subset factors grow by `cholesky_append` borders while
    /// the stride holds, and must (a) numerically match from-scratch
    /// refactors and (b) keep selecting the same lengthscale as the
    /// uncached reference through growth and across a stride jump.
    #[test]
    fn subset_factors_grow_by_appends_without_changing_selection() {
        let base = GpSurrogate::default();
        let (sv, noise) = (base.signal_var, base.noise);

        // (a) Direct machinery check: factors grown from n = 60's subset
        // to n = 80's match the from-scratch n = 80 factors (one stride
        // regime throughout).
        let (x80, _) = toy_data(80, 4, 11);
        let (stride60, idx60) = subset_indices(60);
        let (stride80, idx80) = subset_indices(80);
        assert_eq!(stride60, stride80, "test premise: one stride regime");
        assert!(idx60.len() < idx80.len(), "test premise: the subset grows");
        let d2s = subset_d2(&x80, &idx60);
        let chol: Vec<Option<Matrix>> =
            LS_GRID.iter().map(|&ls| kernel_chol_from_d2(&d2s, ls, sv, noise)).collect();
        let mut s = SubsetSelect { stride: stride60, idx: idx60, chol };
        assert!(grow_subset_factors(&mut s, &idx80, &x80, sv, noise), "appends must hold PD");
        assert_eq!(s.idx, idx80);
        let d2f = subset_d2(&x80, &idx80);
        for (li, &ls) in LS_GRID.iter().enumerate() {
            let grown = s.chol[li].as_ref().expect("grown factor");
            let full = kernel_chol_from_d2(&d2f, ls, sv, noise).expect("full factor");
            assert_eq!(grown.rows, full.rows);
            for i in 0..full.rows {
                for j in 0..=i {
                    assert!(
                        (grown[(i, j)] - full[(i, j)]).abs() < 1e-9,
                        "ls {ls}: factor drift at ({i}, {j})"
                    );
                }
            }
        }

        // (b) End-to-end: the cached ranking tracks the from-scratch
        // reference exactly through append growth (stride 2, n = 49..96)
        // and across the stride jump to 3 at n = 97.
        let mut rng = Rng::new(4242);
        let mut session = IncrementalGp::default();
        let mut rows: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for i in 0..100 {
            let xi: Vec<f64> = (0..4).map(|_| rng.f64()).collect();
            let yi = xi.iter().sum::<f64>().sin() * 3.0 + 10.0 + 0.05 * rng.normal();
            session.observe(xi.clone(), yi);
            rows.push(xi);
            ys.push(yi);
            if i + 1 > LML_SUBSET_MAX {
                let (z, _, _) = standardize(&ys);
                let cached = session.select_ls_subset_cached(&z);
                let scratch = select_ls_downsampled(&Matrix::from_rows(&rows), &z, sv, noise);
                assert_eq!(cached, scratch, "selection diverged at n = {}", i + 1);
            }
        }
    }

    #[test]
    fn incremental_handles_duplicate_observations() {
        // Duplicated inputs stress the appended pivot (kernel row equals
        // an existing row up to jitter): cholesky_append loses positive
        // definiteness, the factor rebuilds with full_chol, and the
        // whitened cache must rebuild with it — both the plain and the
        // pinned session must stay usable and keep matching the full
        // refit.
        let (x, y) = toy_data(6, 3, 9);
        let mut session = IncrementalGp::default();
        let mut whitened = IncrementalGp::default();
        whitened.pin_candidates(&x);
        let mut reference = GpSurrogate::default();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys_all: Vec<f64> = Vec::new();
        for _ in 0..3 {
            for (i, &yi) in y.iter().enumerate() {
                session.observe(x.row(i).to_vec(), yi);
                whitened.observe(x.row(i).to_vec(), yi);
                xs.push(x.row(i).to_vec());
                ys_all.push(yi);
            }
        }
        let ps = session.predict(&x);
        let pw = whitened.predict_pinned();
        let pf = reference.fit_predict(&Matrix::from_rows(&xs), &ys_all, &x);
        for j in 0..x.rows {
            assert!((ps.mean[j] - pf.mean[j]).abs() < 1e-6);
            assert!((ps.std[j] - pf.std[j]).abs() < 1e-6);
            assert!((pw.mean[j] - pf.mean[j]).abs() < 1e-6, "whitened mean after rebuild");
            assert!((pw.std[j] - pf.std[j]).abs() < 1e-6, "whitened std after rebuild");
        }
    }

    /// The pinned whitened path must agree with the unpinned path within
    /// the parity tolerance at every step (the two compute the same
    /// posterior through different factorizations of the same solve).
    #[test]
    fn pinned_predictions_match_unpinned_path() {
        let (x, y) = toy_data(18, 4, 7);
        let cands = toy_data(9, 4, 8).0;
        let mut pinned = IncrementalGp::default();
        pinned.pin_candidates(&cands);
        let mut plain = IncrementalGp::default();
        for (i, &yi) in y.iter().enumerate() {
            pinned.observe(x.row(i).to_vec(), yi);
            plain.observe(x.row(i).to_vec(), yi);
            let a = pinned.predict_pinned();
            let b = plain.predict(&cands);
            assert_eq!(pinned.last_lengthscale, plain.last_lengthscale);
            for j in 0..cands.rows {
                assert!((a.mean[j] - b.mean[j]).abs() < 1e-6, "cand {j} mean");
                // The variance path is algebraically identical (same
                // whitened column norms) — bitwise equal.
                assert_eq!(a.std[j].to_bits(), b.std[j].to_bits(), "cand {j} std");
            }
        }
    }

    /// A session pinned after its observations (wholesale whitening via
    /// `solve_lower_multi`) must be *bit-identical* to one pinned up
    /// front (row-at-a-time whitened appends): the rebuild and append
    /// paths perform the same operations in the same order.
    #[test]
    fn late_pin_is_bit_identical_to_grown_pin() {
        let (x, y) = toy_data(18, 4, 7);
        let cands = toy_data(9, 4, 8).0;
        let mut grown = IncrementalGp::default();
        grown.pin_candidates(&cands);
        let mut late = IncrementalGp::default();
        for (i, &yi) in y.iter().enumerate() {
            grown.observe(x.row(i).to_vec(), yi);
            late.observe(x.row(i).to_vec(), yi);
        }
        late.pin_candidates(&cands);
        let a = grown.predict_pinned();
        let b = late.predict_pinned();
        for j in 0..cands.rows {
            assert_eq!(a.mean[j].to_bits(), b.mean[j].to_bits());
            assert_eq!(a.std[j].to_bits(), b.std[j].to_bits());
        }
    }

    /// Re-pinning to a different grid invalidates the kernel-row and
    /// whitened caches wholesale; predictions on the new grid (and after
    /// further observations growing the caches row by row) stay within
    /// parity tolerance of the uncached path, with the variance path
    /// still bitwise equal.
    #[test]
    fn repinning_invalidates_whitened_cache() {
        let (x, y) = toy_data(14, 3, 11);
        let grid_a = toy_data(6, 3, 12).0;
        let grid_b = toy_data(9, 3, 13).0;
        let mut cached = IncrementalGp::default();
        cached.pin_candidates(&grid_a);
        let mut plain = IncrementalGp::default();
        for (i, &yi) in y.iter().enumerate().take(8) {
            cached.observe(x.row(i).to_vec(), yi);
            plain.observe(x.row(i).to_vec(), yi);
        }
        // Switch grids mid-session, then keep observing.
        cached.pin_candidates(&grid_b);
        for (i, &yi) in y.iter().enumerate().skip(8) {
            cached.observe(x.row(i).to_vec(), yi);
            plain.observe(x.row(i).to_vec(), yi);
        }
        let a = cached.predict_pinned();
        let b = plain.predict(&grid_b);
        assert_eq!(cached.last_lengthscale, plain.last_lengthscale);
        for j in 0..grid_b.rows {
            assert!((a.mean[j] - b.mean[j]).abs() < 1e-6);
            assert_eq!(a.std[j].to_bits(), b.std[j].to_bits());
        }
    }

    /// The whitened pinned path performs **zero per-candidate triangular
    /// solves**: at n <= LML_SUBSET_MAX, exactly one `solve_lower` per
    /// live lengthscale for model selection, regardless of how many
    /// candidates are pinned. (Past the subset threshold, selection
    /// instead costs up to |LS_GRID| bounded <=48-dim subset solves plus
    /// the single full-size solve at the winner — still none per
    /// candidate.) Debug builds only — the counter compiles out in
    /// release.
    #[cfg(debug_assertions)]
    #[test]
    fn predict_pinned_performs_no_per_candidate_solves() {
        use crate::linalg::solve_lower_calls;
        let (x, y) = toy_data(12, 3, 21);
        let m = 60; // far more candidates than lengthscales
        let cands = toy_data(m, 3, 22).0;
        let mut sess = IncrementalGp::default();
        sess.pin_candidates(&cands);
        for (i, &yi) in y.iter().enumerate() {
            sess.observe(x.row(i).to_vec(), yi);
        }
        let before = solve_lower_calls();
        let p = sess.predict_pinned();
        let solves = solve_lower_calls() - before;
        assert_eq!(p.mean.len(), m);
        assert_eq!(
            solves,
            LS_GRID.len() as u64,
            "predict_pinned must solve once per lengthscale (model selection), \
             never per candidate"
        );
        // The unpinned path whitens candidate-rich calls (m > n) on
        // the fly: one uncounted `solve_lower_multi` over the block,
        // so the counted solves are the model-selection ones only.
        let before = solve_lower_calls();
        let _ = sess.predict(&cands);
        let unpinned = solve_lower_calls() - before;
        assert_eq!(
            unpinned,
            LS_GRID.len() as u64,
            "unpinned predict with m > n must whiten wholesale, never solve per candidate"
        );
        // A candidate-poor call (m <= n) keeps the per-candidate loop.
        let few = toy_data(5, 3, 23).0;
        let before = solve_lower_calls();
        let _ = sess.predict(&few);
        let poor = solve_lower_calls() - before;
        assert_eq!(poor, (LS_GRID.len() + 5) as u64);
    }

    /// The whitened unpinned path (m > n) and the per-candidate loop
    /// are the same posterior: force both over the identical session
    /// and candidate set, and pin means/stds within 1e-6.
    #[test]
    fn unpinned_whitened_path_matches_per_candidate_posterior() {
        let (x, y) = toy_data(12, 3, 21);
        let cands = toy_data(60, 3, 22).0;
        let mut sess = IncrementalGp::default();
        for (i, &yi) in y.iter().enumerate() {
            sess.observe(x.row(i).to_vec(), yi);
        }
        // Whitened: one call over the full candidate-rich set.
        let whitened = sess.predict(&cands);
        // Per-candidate reference: the same candidates one at a time
        // (m = 1 <= n keeps each call on the per-candidate loop).
        for j in 0..cands.rows {
            let mut one = Matrix::zeros(1, cands.cols);
            for c in 0..cands.cols {
                one[(0, c)] = cands[(j, c)];
            }
            let reference = sess.predict(&one);
            assert!(
                (whitened.mean[j] - reference.mean[0]).abs() < 1e-6,
                "mean[{j}]: {} vs {}",
                whitened.mean[j],
                reference.mean[0]
            );
            assert!(
                (whitened.std[j] - reference.std[0]).abs() < 1e-6,
                "std[{j}]: {} vs {}",
                whitened.std[j],
                reference.std[0]
            );
        }
    }

    #[test]
    fn incremental_single_observation() {
        let mut s = IncrementalGp::default();
        s.observe(vec![0.5, 0.5], 3.0);
        assert_eq!(s.n_obs(), 1);
        let p = s.predict(&Matrix::from_rows(&[vec![0.5, 0.5], vec![0.9, 0.1]]));
        assert_eq!(p.mean.len(), 2);
        assert!(p.mean.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn constant_targets_do_not_crash() {
        let (x, _) = toy_data(8, 2, 5);
        let y = vec![2.0; 8];
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &x);
        for m in p.mean {
            assert!((m - 2.0).abs() < 0.1);
        }
    }
}
