//! Native Matern-5/2 Gaussian-process surrogate.
//!
//! Mirrors the math of the AOT artifact (`python/compile/model.py`)
//! exactly — masked padding aside — so the two backends are
//! interchangeable and cross-checked by the parity integration test:
//! same kernel, same jitter, same y-standardization convention, same
//! lengthscale grid selected by log marginal likelihood.

use super::{standardize, GpSession, Prediction, Surrogate};
use crate::linalg::{cholesky, cholesky_append, solve_lower, solve_upper_t, Matrix};

/// Matches `JITTER` in python/compile/model.py.
pub const JITTER: f64 = 1e-5;

/// Lengthscale grid searched by marginal likelihood at each fit. The
/// encoded domain lives on the unit hypercube, so order-1 scales cover it.
pub const LS_GRID: [f64; 4] = [0.35, 0.7, 1.4, 2.8];

#[derive(Clone, Debug)]
pub struct GpSurrogate {
    /// Observation noise variance (on standardized y).
    pub noise: f64,
    /// Signal variance (standardized y: 1.0).
    pub signal_var: f64,
    /// Chosen lengthscale from the last fit (for inspection/tests).
    pub last_lengthscale: f64,
}

impl Default for GpSurrogate {
    fn default() -> Self {
        GpSurrogate { noise: 1e-2, signal_var: 1.0, last_lengthscale: LS_GRID[1] }
    }
}

pub fn matern52(d2: f64, lengthscale: f64, signal_var: f64) -> f64 {
    let u = (5.0 * d2).sqrt() / lengthscale;
    signal_var * (1.0 + u + u * u / 3.0) * (-u).exp()
}

fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

struct Fitted {
    l: Matrix,
    alpha: Vec<f64>,
    lml: f64,
}

/// The posterior loop shared by every GP prediction path (full refit,
/// incremental, kernel-row cached): for each of `m` candidates,
/// `fill_kxc(j, &mut kxc)` writes K(X, cand_j) and the same mean /
/// variance math runs on top. One body, so the bit-identity contract
/// between the paths rests on shared code, not on hand-synchronized
/// copies of the loop.
fn posterior_over(
    l: &Matrix,
    alpha: &[f64],
    signal_var: f64,
    ym: f64,
    ys: f64,
    m: usize,
    mut fill_kxc: impl FnMut(usize, &mut [f64]),
) -> Prediction {
    let n = alpha.len();
    let mut mean = Vec::with_capacity(m);
    let mut std = Vec::with_capacity(m);
    let mut kxc = vec![0.0; n];
    for j in 0..m {
        fill_kxc(j, &mut kxc);
        let mu: f64 = kxc.iter().zip(alpha).map(|(a, b)| a * b).sum();
        let v = solve_lower(l, &kxc);
        let var = (signal_var - v.iter().map(|t| t * t).sum::<f64>()).max(1e-12);
        mean.push(mu * ys + ym);
        std.push(var.sqrt() * ys);
    }
    Prediction { mean, std }
}

/// Fit from a precomputed observation-observation squared-distance matrix
/// (the distance computation is shared across the lengthscale grid — the
/// §Perf L3 optimization, ~4x fewer O(n^2 d) passes per BO iteration).
fn fit_from_d2(d2: &Matrix, z: &[f64], ls: f64, sv: f64, noise: f64) -> Option<Fitted> {
    let n = z.len();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(d2[(i, j)], ls, sv);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += noise + JITTER;
    }
    let l = cholesky(&k)?;
    let alpha = solve_upper_t(&l, &solve_lower(&l, z));
    let quad: f64 = z.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let logdet: f64 = (0..n).map(|i| l[(i, i)].ln()).sum();
    let lml = -0.5 * quad - logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    Some(Fitted { l, alpha, lml })
}

impl Surrogate for GpSurrogate {
    fn fit_predict(&mut self, x: &[Vec<f64>], y: &[f64], cands: &[Vec<f64>]) -> Prediction {
        assert!(!x.is_empty(), "GP fit with no observations");
        assert_eq!(x.len(), y.len());
        let (z, ym, ys) = standardize(y);
        let n = x.len();
        let m = cands.len();

        // Shared distance matrices (reused by all 4 lengthscale fits).
        let mut d2xx = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = sqdist(&x[i], &x[j]);
                d2xx[(i, j)] = v;
                d2xx[(j, i)] = v;
            }
        }
        let mut d2xc = Matrix::zeros(n, m);
        for i in 0..n {
            for (j, c) in cands.iter().enumerate() {
                d2xc[(i, j)] = sqdist(&x[i], c);
            }
        }

        // Model selection: pick the lengthscale maximizing the marginal
        // likelihood (the artifact path does the same via repeated
        // executions with different hyp vectors).
        let mut best: Option<(f64, Fitted)> = None;
        for &ls in &LS_GRID {
            if let Some(f) = fit_from_d2(&d2xx, &z, ls, self.signal_var, self.noise) {
                if best.as_ref().map(|(_, b)| f.lml > b.lml).unwrap_or(true) {
                    best = Some((ls, f));
                }
            }
        }
        let (ls, fitted) =
            best.expect("GP fit failed for every lengthscale (should be impossible with jitter)");
        self.last_lengthscale = ls;

        let sv = self.signal_var;
        posterior_over(&fitted.l, &fitted.alpha, sv, ym, ys, m, |j, kxc| {
            for i in 0..n {
                kxc[i] = matern52(d2xc[(i, j)], ls, sv);
            }
        })
    }
}

// ---------------------------------------------------------------------------
// Incremental session: O(n²) per added observation instead of O(n³).

/// Build the full Cholesky factor of K(X,X) + (noise + jitter) I for one
/// lengthscale — the reference path the incremental appends must match.
fn full_chol(x: &[Vec<f64>], ls: f64, sv: f64, noise: f64) -> Option<Matrix> {
    let n = x.len();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(sqdist(&x[i], &x[j]), ls, sv);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += noise + JITTER;
    }
    cholesky(&k)
}

/// Stateful Matern-5/2 GP session with **incremental** Cholesky updates.
///
/// The kernel matrix depends only on the inputs, so one factorization is
/// cached per [`LS_GRID`] entry and grown by a rank-1 border
/// ([`cholesky_append`]) per new observation — O(n²) instead of the
/// O(n³) full refit every BO iteration pays otherwise. Everything that
/// depends on y (standardization, alpha, the log marginal likelihood
/// driving lengthscale selection) is recomputed per predict from the
/// cached factor via two triangular solves, so model selection is
/// semantically identical to [`GpSurrogate::fit_predict`]; the parity
/// tests below assert agreement within 1e-6.
pub struct IncrementalGp {
    /// Observation noise variance (on standardized y).
    pub noise: f64,
    /// Signal variance (standardized y: 1.0).
    pub signal_var: f64,
    /// Chosen lengthscale from the last predict (for inspection/tests).
    pub last_lengthscale: f64,
    x: Vec<Vec<f64>>,
    y: Vec<f64>,
    /// One cached factor per lengthscale-grid point; None when the
    /// bordered matrix lost positive definiteness and the rebuild also
    /// failed (that lengthscale then sits out model selection, exactly
    /// like a failed `fit_from_d2`).
    chol: Vec<Option<Matrix>>,
    /// Pinned candidate set (the BO loop predicts over one fixed grid).
    pinned: Vec<Vec<f64>>,
    /// pinned_d2[i][j] = d²(x_i, pinned[j]); one row appended per
    /// observation, so `predict_pinned` never recomputes the O(n·m·d)
    /// distance pass the unpinned path pays every iteration.
    pinned_d2: Vec<Vec<f64>>,
    /// pinned_k[li][i][j] = matern52(pinned_d2[i][j], LS_GRID[li]): the
    /// kernel rows one level below the distance cache. Only the appended
    /// row is computed per observation, so `predict_pinned` also skips
    /// the O(n·m) re-kernelization of cached distances that
    /// `posterior_from_d2` pays per predict. Rebuilt wholesale when the
    /// candidate set is (re)pinned.
    pinned_k: Vec<Vec<Vec<f64>>>,
}

impl Default for IncrementalGp {
    fn default() -> Self {
        let base = GpSurrogate::default();
        IncrementalGp {
            noise: base.noise,
            signal_var: base.signal_var,
            last_lengthscale: base.last_lengthscale,
            x: Vec::new(),
            y: Vec::new(),
            chol: vec![None; LS_GRID.len()],
            pinned: Vec::new(),
            pinned_d2: Vec::new(),
            pinned_k: vec![Vec::new(); LS_GRID.len()],
        }
    }
}

impl IncrementalGp {
    /// Model selection over the cached factors: the (grid index, alpha)
    /// maximizing the log marginal likelihood on standardized targets.
    fn select_model(&self, z: &[f64]) -> (usize, Vec<f64>) {
        let n = z.len();
        let mut best: Option<(usize, Vec<f64>, f64)> = None;
        for li in 0..LS_GRID.len() {
            let Some(l) = &self.chol[li] else { continue };
            let alpha = solve_upper_t(l, &solve_lower(l, z));
            let quad: f64 = z.iter().zip(&alpha).map(|(a, b)| a * b).sum();
            let logdet: f64 = (0..n).map(|i| l[(i, i)].ln()).sum();
            let lml = -0.5 * quad - logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
            if best.as_ref().map(|(_, _, b)| lml > *b).unwrap_or(true) {
                best = Some((li, alpha, lml));
            }
        }
        let (li, alpha, _) =
            best.expect("GP fit failed for every lengthscale (should be impossible with jitter)");
        (li, alpha)
    }

    /// Posterior from precomputed observation-candidate squared
    /// distances (`d2[i][j] = d²(x_i, cand_j)`, `m` candidates).
    fn posterior_from_d2(&mut self, m: usize, d2: &[Vec<f64>]) -> Prediction {
        assert!(!self.x.is_empty(), "GP predict with no observations");
        let (z, ym, ys) = standardize(&self.y);
        let (li, alpha) = self.select_model(&z);
        let ls = LS_GRID[li];
        self.last_lengthscale = ls;
        let l = self.chol[li].as_ref().unwrap();

        let sv = self.signal_var;
        posterior_over(l, &alpha, sv, ym, ys, m, |j, kxc| {
            for (i, row) in d2.iter().enumerate() {
                kxc[i] = matern52(row[j], ls, sv);
            }
        })
    }
}

impl GpSession for IncrementalGp {
    fn observe(&mut self, x_new: Vec<f64>, y_new: f64) {
        let n_prev = self.x.len();
        self.x.push(x_new);
        self.y.push(y_new);
        let diag = self.signal_var + self.noise + JITTER;
        for (li, &ls) in LS_GRID.iter().enumerate() {
            let appended = match &self.chol[li] {
                Some(l) if l.rows == n_prev => {
                    let xn = &self.x[n_prev];
                    let k_new: Vec<f64> = self.x[..n_prev]
                        .iter()
                        .map(|xi| matern52(sqdist(xi, xn), ls, self.signal_var))
                        .collect();
                    cholesky_append(l, &k_new, diag)
                }
                _ => None,
            };
            self.chol[li] =
                appended.or_else(|| full_chol(&self.x, ls, self.signal_var, self.noise));
        }
        // Grow the pinned-candidate distance and kernel-row caches by
        // one row each; every earlier row is unchanged by construction
        // (the kernel depends only on inputs and the fixed grid).
        if !self.pinned.is_empty() {
            let xn = &self.x[n_prev];
            let d2_row: Vec<f64> = self.pinned.iter().map(|c| sqdist(xn, c)).collect();
            for (li, &ls) in LS_GRID.iter().enumerate() {
                self.pinned_k[li]
                    .push(d2_row.iter().map(|&d2| matern52(d2, ls, self.signal_var)).collect());
            }
            self.pinned_d2.push(d2_row);
        }
    }

    fn predict(&mut self, cands: &[Vec<f64>]) -> Prediction {
        let d2: Vec<Vec<f64>> = self
            .x
            .iter()
            .map(|xi| cands.iter().map(|c| sqdist(xi, c)).collect())
            .collect();
        self.posterior_from_d2(cands.len(), &d2)
    }

    fn pin_candidates(&mut self, cands: &[Vec<f64>]) {
        self.pinned = cands.to_vec();
        self.pinned_d2 = self
            .x
            .iter()
            .map(|xi| cands.iter().map(|c| sqdist(xi, c)).collect())
            .collect();
        // Invalidate and rebuild the kernel rows for the new grid.
        self.pinned_k = LS_GRID
            .iter()
            .map(|&ls| {
                self.pinned_d2
                    .iter()
                    .map(|row| row.iter().map(|&d2| matern52(d2, ls, self.signal_var)).collect())
                    .collect()
            })
            .collect();
    }

    fn predict_pinned(&mut self) -> Prediction {
        assert!(!self.pinned.is_empty(), "predict_pinned without pinned candidates");
        assert!(!self.x.is_empty(), "GP predict with no observations");
        let m = self.pinned.len();
        let (z, ym, ys) = standardize(&self.y);
        let (li, alpha) = self.select_model(&z);
        self.last_lengthscale = LS_GRID[li];
        let l = self.chol[li].as_ref().unwrap();
        // Same shared posterior loop as `posterior_from_d2`, but the
        // kernel values come straight from the per-lengthscale row
        // cache — matern52 applied to the identical d² at observe/pin
        // time, so the result is bit-identical to the uncached path.
        let rows = &self.pinned_k[li];
        posterior_over(l, &alpha, self.signal_var, ym, ys, m, |j, kxc| {
            for (i, row) in rows.iter().enumerate() {
                kxc[i] = row[j];
            }
        })
    }

    fn n_obs(&self) -> usize {
        self.y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> =
            x.iter().map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0).collect();
        (x, y)
    }

    #[test]
    fn interpolates_at_training_points() {
        let (x, y) = toy_data(20, 4, 1);
        let mut gp = GpSurrogate { noise: 1e-6, ..Default::default() };
        let pred = gp.fit_predict(&x, &y, &x);
        for (m, yv) in pred.mean.iter().zip(&y) {
            assert!((m - yv).abs() < 0.05, "{m} vs {yv}");
        }
        // Tiny predictive std at observed points.
        assert!(pred.std.iter().all(|&s| s < 0.2));
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let (x, y) = toy_data(10, 3, 2);
        let mut gp = GpSurrogate::default();
        let far = vec![vec![10.0; 3]];
        let near = vec![x[0].clone()];
        let p_far = gp.fit_predict(&x, &y, &far);
        let p_near = gp.fit_predict(&x, &y, &near);
        assert!(p_far.std[0] > 3.0 * p_near.std[0]);
    }

    #[test]
    fn far_prediction_reverts_to_prior_mean() {
        let (x, y) = toy_data(15, 3, 3);
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &[vec![50.0; 3]]);
        let ym = crate::util::stats::mean(&y);
        assert!((p.mean[0] - ym).abs() < 0.5);
    }

    #[test]
    fn lengthscale_selection_adapts() {
        // Smooth function -> long lengthscale beats the shortest one.
        let mut rng = Rng::new(4);
        let x: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.f64()]).collect();
        let y: Vec<f64> = x.iter().map(|v| v[0] * 2.0 + 1.0).collect();
        let mut gp = GpSurrogate::default();
        gp.fit_predict(&x, &y, &x);
        assert!(gp.last_lengthscale > LS_GRID[0]);
    }

    #[test]
    fn matern_kernel_values() {
        assert!((matern52(0.0, 1.0, 2.0) - 2.0).abs() < 1e-12);
        let u = 5.0f64.sqrt();
        let want = (1.0 + u + u * u / 3.0) * (-u).exp();
        assert!((matern52(1.0, 1.0, 1.0) - want).abs() < 1e-12);
    }

    #[test]
    fn handles_single_observation() {
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&[vec![0.5, 0.5]], &[3.0], &[vec![0.5, 0.5], vec![0.9, 0.1]]);
        assert_eq!(p.mean.len(), 2);
        assert!(p.mean.iter().all(|m| m.is_finite()));
    }

    /// Randomized incremental/full parity suite: a session grown one
    /// observation at a time must agree with the full-refit reference
    /// within 1e-6 at every step, and select the same lengthscale.
    #[test]
    fn incremental_matches_full_refit_within_1e6() {
        crate::testkit::check("incremental GP parity", 12, |g| {
            let d = g.usize_in(1, 6);
            let n = g.usize_in(2, 24);
            let m = g.usize_in(1, 12);
            let rng = g.rng();
            let x: Vec<Vec<f64>> =
                (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
            let y: Vec<f64> = x
                .iter()
                .map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0 + 0.05 * rng.normal())
                .collect();
            let cands: Vec<Vec<f64>> =
                (0..m).map(|_| (0..d).map(|_| rng.f64() * 1.5).collect()).collect();

            let mut session = IncrementalGp::default();
            let mut reference = GpSurrogate::default();
            for i in 0..n {
                session.observe(x[i].clone(), y[i]);
                // Check parity at a few prefix lengths, always at the end.
                if i + 1 == n || i % 5 == 4 {
                    let ps = session.predict(&cands);
                    let pf = reference.fit_predict(&x[..=i], &y[..=i], &cands);
                    assert_eq!(session.last_lengthscale, reference.last_lengthscale);
                    for j in 0..m {
                        assert!(
                            (ps.mean[j] - pf.mean[j]).abs() < 1e-6,
                            "n={} cand {j}: mean {} vs {}",
                            i + 1,
                            ps.mean[j],
                            pf.mean[j]
                        );
                        assert!(
                            (ps.std[j] - pf.std[j]).abs() < 1e-6,
                            "n={} cand {j}: std {} vs {}",
                            i + 1,
                            ps.std[j],
                            pf.std[j]
                        );
                    }
                }
            }
        });
    }

    /// Parity at the paper's largest budget: 88 successive appends on
    /// one factor must not drift past 1e-6 from the full refit (the
    /// randomized suite above caps n at 24; this pins the deep end).
    #[test]
    fn incremental_parity_at_budget_scale() {
        let mut rng = Rng::new(88);
        let d = 5;
        let n = 88;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0 + 0.05 * rng.normal())
            .collect();
        let cands: Vec<Vec<f64>> =
            (0..8).map(|_| (0..d).map(|_| rng.f64() * 1.5).collect()).collect();
        let mut session = IncrementalGp::default();
        let mut reference = GpSurrogate::default();
        for i in 0..n {
            session.observe(x[i].clone(), y[i]);
            if [24, 48, 88].contains(&(i + 1)) {
                let ps = session.predict(&cands);
                let pf = reference.fit_predict(&x[..=i], &y[..=i], &cands);
                assert_eq!(session.last_lengthscale, reference.last_lengthscale);
                for j in 0..cands.len() {
                    assert!(
                        (ps.mean[j] - pf.mean[j]).abs() < 1e-6
                            && (ps.std[j] - pf.std[j]).abs() < 1e-6,
                        "n={}: cand {j} mean {} vs {} / std {} vs {}",
                        i + 1,
                        ps.mean[j],
                        pf.mean[j],
                        ps.std[j],
                        pf.std[j]
                    );
                }
            }
        }
    }

    #[test]
    fn incremental_handles_duplicate_observations() {
        // Duplicated inputs stress the appended pivot (kernel row equals
        // an existing row up to jitter); the session must stay usable and
        // keep matching the full refit.
        let (x, y) = toy_data(6, 3, 9);
        let mut session = IncrementalGp::default();
        let mut reference = GpSurrogate::default();
        let mut xs: Vec<Vec<f64>> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        for _ in 0..3 {
            for (xi, &yi) in x.iter().zip(&y) {
                session.observe(xi.clone(), yi);
                xs.push(xi.clone());
                ys.push(yi);
            }
        }
        let ps = session.predict(&x);
        let pf = reference.fit_predict(&xs, &ys, &x);
        for j in 0..x.len() {
            assert!((ps.mean[j] - pf.mean[j]).abs() < 1e-6);
            assert!((ps.std[j] - pf.std[j]).abs() < 1e-6);
        }
    }

    /// The pinned-candidate fast path must be bit-identical to the
    /// unpinned path: the cached d² rows are the same f64s `predict`
    /// recomputes, fed through the same posterior code.
    #[test]
    fn pinned_predictions_are_bit_identical_to_unpinned() {
        let (x, y) = toy_data(18, 4, 7);
        let cands: Vec<Vec<f64>> = toy_data(9, 4, 8).0;
        let mut pinned = IncrementalGp::default();
        pinned.pin_candidates(&cands);
        let mut plain = IncrementalGp::default();
        for (xi, &yi) in x.iter().zip(&y) {
            pinned.observe(xi.clone(), yi);
            plain.observe(xi.clone(), yi);
            let a = pinned.predict_pinned();
            let b = plain.predict(&cands);
            assert_eq!(pinned.last_lengthscale, plain.last_lengthscale);
            for j in 0..cands.len() {
                assert_eq!(a.mean[j].to_bits(), b.mean[j].to_bits());
                assert_eq!(a.std[j].to_bits(), b.std[j].to_bits());
            }
        }
        // Pinning after observations (the replay/rebuild path) agrees too.
        let mut late = IncrementalGp::default();
        for (xi, &yi) in x.iter().zip(&y) {
            late.observe(xi.clone(), yi);
        }
        late.pin_candidates(&cands);
        let a = late.predict_pinned();
        let b = plain.predict(&cands);
        for j in 0..cands.len() {
            assert_eq!(a.mean[j].to_bits(), b.mean[j].to_bits());
        }
    }

    /// Re-pinning to a different grid invalidates the kernel-row cache
    /// wholesale; predictions on the new grid (and after further
    /// observations growing the cache row by row) stay bit-identical to
    /// the uncached path.
    #[test]
    fn repinning_invalidates_kernel_row_cache() {
        let (x, y) = toy_data(14, 3, 11);
        let grid_a: Vec<Vec<f64>> = toy_data(6, 3, 12).0;
        let grid_b: Vec<Vec<f64>> = toy_data(9, 3, 13).0;
        let mut cached = IncrementalGp::default();
        cached.pin_candidates(&grid_a);
        let mut plain = IncrementalGp::default();
        for (xi, &yi) in x.iter().take(8).zip(&y) {
            cached.observe(xi.clone(), yi);
            plain.observe(xi.clone(), yi);
        }
        // Switch grids mid-session, then keep observing.
        cached.pin_candidates(&grid_b);
        for (xi, &yi) in x.iter().zip(&y).skip(8) {
            cached.observe(xi.clone(), yi);
            plain.observe(xi.clone(), yi);
        }
        let a = cached.predict_pinned();
        let b = plain.predict(&grid_b);
        assert_eq!(cached.last_lengthscale, plain.last_lengthscale);
        for j in 0..grid_b.len() {
            assert_eq!(a.mean[j].to_bits(), b.mean[j].to_bits());
            assert_eq!(a.std[j].to_bits(), b.std[j].to_bits());
        }
    }

    #[test]
    fn incremental_single_observation() {
        let mut s = IncrementalGp::default();
        s.observe(vec![0.5, 0.5], 3.0);
        assert_eq!(s.n_obs(), 1);
        let p = s.predict(&[vec![0.5, 0.5], vec![0.9, 0.1]]);
        assert_eq!(p.mean.len(), 2);
        assert!(p.mean.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn constant_targets_do_not_crash() {
        let (x, _) = toy_data(8, 2, 5);
        let y = vec![2.0; 8];
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &x);
        for m in p.mean {
            assert!((m - 2.0).abs() < 0.1);
        }
    }
}
