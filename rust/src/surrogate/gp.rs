//! Native Matern-5/2 Gaussian-process surrogate.
//!
//! Mirrors the math of the AOT artifact (`python/compile/model.py`)
//! exactly — masked padding aside — so the two backends are
//! interchangeable and cross-checked by the parity integration test:
//! same kernel, same jitter, same y-standardization convention, same
//! lengthscale grid selected by log marginal likelihood.

use super::{standardize, Prediction, Surrogate};
use crate::linalg::{cholesky, solve_lower, solve_upper_t, Matrix};

/// Matches `JITTER` in python/compile/model.py.
pub const JITTER: f64 = 1e-5;

/// Lengthscale grid searched by marginal likelihood at each fit. The
/// encoded domain lives on the unit hypercube, so order-1 scales cover it.
pub const LS_GRID: [f64; 4] = [0.35, 0.7, 1.4, 2.8];

#[derive(Clone, Debug)]
pub struct GpSurrogate {
    /// Observation noise variance (on standardized y).
    pub noise: f64,
    /// Signal variance (standardized y: 1.0).
    pub signal_var: f64,
    /// Chosen lengthscale from the last fit (for inspection/tests).
    pub last_lengthscale: f64,
}

impl Default for GpSurrogate {
    fn default() -> Self {
        GpSurrogate { noise: 1e-2, signal_var: 1.0, last_lengthscale: LS_GRID[1] }
    }
}

pub fn matern52(d2: f64, lengthscale: f64, signal_var: f64) -> f64 {
    let u = (5.0 * d2).sqrt() / lengthscale;
    signal_var * (1.0 + u + u * u / 3.0) * (-u).exp()
}

fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

struct Fitted {
    l: Matrix,
    alpha: Vec<f64>,
    lml: f64,
}

/// Fit from a precomputed observation-observation squared-distance matrix
/// (the distance computation is shared across the lengthscale grid — the
/// §Perf L3 optimization, ~4x fewer O(n^2 d) passes per BO iteration).
fn fit_from_d2(d2: &Matrix, z: &[f64], ls: f64, sv: f64, noise: f64) -> Option<Fitted> {
    let n = z.len();
    let mut k = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = matern52(d2[(i, j)], ls, sv);
            k[(i, j)] = v;
            k[(j, i)] = v;
        }
        k[(i, i)] += noise + JITTER;
    }
    let l = cholesky(&k)?;
    let alpha = solve_upper_t(&l, &solve_lower(&l, z));
    let quad: f64 = z.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let logdet: f64 = (0..n).map(|i| l[(i, i)].ln()).sum();
    let lml = -0.5 * quad - logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    Some(Fitted { l, alpha, lml })
}

impl Surrogate for GpSurrogate {
    fn fit_predict(&mut self, x: &[Vec<f64>], y: &[f64], cands: &[Vec<f64>]) -> Prediction {
        assert!(!x.is_empty(), "GP fit with no observations");
        assert_eq!(x.len(), y.len());
        let (z, ym, ys) = standardize(y);
        let n = x.len();
        let m = cands.len();

        // Shared distance matrices (reused by all 4 lengthscale fits).
        let mut d2xx = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = sqdist(&x[i], &x[j]);
                d2xx[(i, j)] = v;
                d2xx[(j, i)] = v;
            }
        }
        let mut d2xc = Matrix::zeros(n, m);
        for i in 0..n {
            for (j, c) in cands.iter().enumerate() {
                d2xc[(i, j)] = sqdist(&x[i], c);
            }
        }

        // Model selection: pick the lengthscale maximizing the marginal
        // likelihood (the artifact path does the same via repeated
        // executions with different hyp vectors).
        let mut best: Option<(f64, Fitted)> = None;
        for &ls in &LS_GRID {
            if let Some(f) = fit_from_d2(&d2xx, &z, ls, self.signal_var, self.noise) {
                if best.as_ref().map(|(_, b)| f.lml > b.lml).unwrap_or(true) {
                    best = Some((ls, f));
                }
            }
        }
        let (ls, fitted) =
            best.expect("GP fit failed for every lengthscale (should be impossible with jitter)");
        self.last_lengthscale = ls;

        let mut mean = Vec::with_capacity(m);
        let mut std = Vec::with_capacity(m);
        let mut kxc = vec![0.0; n];
        for j in 0..m {
            for i in 0..n {
                kxc[i] = matern52(d2xc[(i, j)], ls, self.signal_var);
            }
            let mu: f64 = kxc.iter().zip(&fitted.alpha).map(|(a, b)| a * b).sum();
            let v = solve_lower(&fitted.l, &kxc);
            let var =
                (self.signal_var - v.iter().map(|t| t * t).sum::<f64>()).max(1e-12);
            mean.push(mu * ys + ym);
            std.push(var.sqrt() * ys);
        }
        Prediction { mean, std }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> =
            x.iter().map(|xi| xi.iter().sum::<f64>().sin() * 3.0 + 10.0).collect();
        (x, y)
    }

    #[test]
    fn interpolates_at_training_points() {
        let (x, y) = toy_data(20, 4, 1);
        let mut gp = GpSurrogate { noise: 1e-6, ..Default::default() };
        let pred = gp.fit_predict(&x, &y, &x);
        for (m, yv) in pred.mean.iter().zip(&y) {
            assert!((m - yv).abs() < 0.05, "{m} vs {yv}");
        }
        // Tiny predictive std at observed points.
        assert!(pred.std.iter().all(|&s| s < 0.2));
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let (x, y) = toy_data(10, 3, 2);
        let mut gp = GpSurrogate::default();
        let far = vec![vec![10.0; 3]];
        let near = vec![x[0].clone()];
        let p_far = gp.fit_predict(&x, &y, &far);
        let p_near = gp.fit_predict(&x, &y, &near);
        assert!(p_far.std[0] > 3.0 * p_near.std[0]);
    }

    #[test]
    fn far_prediction_reverts_to_prior_mean() {
        let (x, y) = toy_data(15, 3, 3);
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &[vec![50.0; 3]]);
        let ym = crate::util::stats::mean(&y);
        assert!((p.mean[0] - ym).abs() < 0.5);
    }

    #[test]
    fn lengthscale_selection_adapts() {
        // Smooth function -> long lengthscale beats the shortest one.
        let mut rng = Rng::new(4);
        let x: Vec<Vec<f64>> = (0..30).map(|_| vec![rng.f64()]).collect();
        let y: Vec<f64> = x.iter().map(|v| v[0] * 2.0 + 1.0).collect();
        let mut gp = GpSurrogate::default();
        gp.fit_predict(&x, &y, &x);
        assert!(gp.last_lengthscale > LS_GRID[0]);
    }

    #[test]
    fn matern_kernel_values() {
        assert!((matern52(0.0, 1.0, 2.0) - 2.0).abs() < 1e-12);
        let u = 5.0f64.sqrt();
        let want = (1.0 + u + u * u / 3.0) * (-u).exp();
        assert!((matern52(1.0, 1.0, 1.0) - want).abs() < 1e-12);
    }

    #[test]
    fn handles_single_observation() {
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&[vec![0.5, 0.5]], &[3.0], &[vec![0.5, 0.5], vec![0.9, 0.1]]);
        assert_eq!(p.mean.len(), 2);
        assert!(p.mean.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn constant_targets_do_not_crash() {
        let (x, _) = toy_data(8, 2, 5);
        let y = vec![2.0; 8];
        let mut gp = GpSurrogate::default();
        let p = gp.fit_predict(&x, &y, &x);
        for m in p.mean {
            assert!((m - 2.0).abs() < 0.1);
        }
    }
}
