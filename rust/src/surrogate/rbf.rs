//! Native cubic-RBF interpolant (RBFOpt-lite's surrogate).
//!
//! phi(r) = r^3 with a constant polynomial tail, fit by solving the
//! (n+1) saddle system with partial-pivoting Gaussian elimination.
//! Mirrors `rbf_forward` in python/compile/model.py (the AOT artifact
//! solves the same system via normal equations in f64); the parity test
//! in rust/tests checks the two agree.
//!
//! Observations and candidates arrive as row-major [`Matrix`] values so
//! the O(n²) distance pass streams over contiguous rows.
//!
//! Besides the interpolant value, the model reports each candidate's
//! distance to the nearest observation — RBFOpt-lite's exploration signal.

use crate::linalg::{solve_general, Matrix};

#[derive(Clone, Debug)]
pub struct RbfFit {
    centers: Matrix,
    coef: Vec<f64>,
    tail: f64,
}

#[derive(Clone, Debug)]
pub struct RbfPrediction {
    pub pred: Vec<f64>,
    pub mindist: Vec<f64>,
}

fn dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn phi(r: f64) -> f64 {
    r * r * r
}

/// Fit the interpolant. `ridge` regularizes the live diagonal (matches the
/// artifact's `lam`). Returns None when the saddle system is singular
/// (e.g. duplicated points with conflicting targets and zero ridge).
pub fn fit(x: &Matrix, y: &[f64], ridge: f64) -> Option<RbfFit> {
    assert_eq!(x.rows, y.len());
    assert!(x.rows > 0);
    let n = x.rows;
    let mut a = Matrix::zeros(n + 1, n + 1);
    for i in 0..n {
        let xi = x.row(i);
        for j in 0..n {
            a[(i, j)] = phi(dist(xi, x.row(j)));
        }
        a[(i, i)] += ridge;
        a[(i, n)] = 1.0;
        a[(n, i)] = 1.0;
    }
    let mut rhs = y.to_vec();
    rhs.push(0.0);
    let z = solve_general(&a, &rhs)?;
    Some(RbfFit { centers: x.clone(), coef: z[..n].to_vec(), tail: z[n] })
}

/// Last-resort degenerate model: a constant interpolant at the mean of
/// the finite targets, with brute-force nearest-observation distances.
/// Used by the backend when even the largest ridge cannot make the
/// saddle system solvable (e.g. non-finite inputs).
pub fn constant_prediction(x: &Matrix, y: &[f64], cands: &Matrix) -> RbfPrediction {
    let finite: Vec<f64> = y.iter().copied().filter(|v| v.is_finite()).collect();
    let level = if finite.is_empty() { 0.0 } else { crate::util::stats::mean(&finite) };
    let mindist = (0..cands.rows)
        .map(|j| {
            let c = cands.row(j);
            (0..x.rows).map(|i| dist(x.row(i), c)).fold(f64::INFINITY, f64::min)
        })
        .collect();
    RbfPrediction { pred: vec![level; cands.rows], mindist }
}

impl RbfFit {
    pub fn predict(&self, cands: &Matrix) -> RbfPrediction {
        let m = cands.rows;
        let mut pred = Vec::with_capacity(m);
        let mut mindist = Vec::with_capacity(m);
        for j in 0..m {
            let c = cands.row(j);
            let mut s = self.tail;
            let mut dmin = f64::INFINITY;
            for (i, coef) in self.coef.iter().enumerate() {
                let r = dist(self.centers.row(i), c);
                s += coef * phi(r);
                dmin = dmin.min(r);
            }
            pred.push(s);
            mindist.push(dmin);
        }
        RbfPrediction { pred, mindist }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f64>> = (0..n).map(|_| (0..d).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = rows.iter().map(|v| v.iter().map(|t| t * t).sum::<f64>()).collect();
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn interpolates_exactly_with_zero_ridge() {
        let (x, y) = toy(15, 3, 1);
        let fit = fit(&x, &y, 0.0).unwrap();
        let p = fit.predict(&x);
        for (got, want) in p.pred.iter().zip(&y) {
            assert!((got - want).abs() < 1e-7, "{got} vs {want}");
        }
        assert!(p.mindist.iter().all(|&d| d < 1e-12));
    }

    #[test]
    fn mindist_matches_bruteforce() {
        let (x, y) = toy(10, 4, 2);
        let fit = fit(&x, &y, 1e-8).unwrap();
        let cands = toy(5, 4, 3).0;
        let p = fit.predict(&cands);
        for (j, got) in p.mindist.iter().enumerate() {
            let c = cands.row(j);
            let want = (0..x.rows).map(|i| dist(x.row(i), c)).fold(f64::INFINITY, f64::min);
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn smooth_generalization_between_points() {
        // 1-D line: interpolant of y = x should stay near x in-between.
        let rows: Vec<Vec<f64>> = (0..=10).map(|i| vec![i as f64 / 10.0]).collect();
        let y: Vec<f64> = rows.iter().map(|v| v[0]).collect();
        let fit = fit(&Matrix::from_rows(&rows), &y, 0.0).unwrap();
        let p = fit.predict(&Matrix::from_rows(&[vec![0.55], vec![0.05]]));
        assert!((p.pred[0] - 0.55).abs() < 0.05);
        assert!((p.pred[1] - 0.05).abs() < 0.05);
    }

    #[test]
    fn duplicate_points_need_ridge() {
        let x = Matrix::from_rows(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        let y = vec![1.0, 2.0];
        assert!(fit(&x, &y, 0.0).is_none());
        let f = fit(&x, &y, 1e-3).unwrap();
        let p = f.predict(&Matrix::from_rows(&[vec![0.5, 0.5]]));
        assert!((p.pred[0] - 1.5).abs() < 0.1);
    }

    #[test]
    fn constant_prediction_uses_mean_and_distances() {
        let x = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 0.0]]);
        let y = vec![2.0, 4.0];
        let p = constant_prediction(&x, &y, &Matrix::from_rows(&[vec![0.0, 3.0]]));
        assert_eq!(p.pred, vec![3.0]);
        assert!((p.mindist[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn single_point_degenerates_to_constant() {
        let f = fit(&Matrix::from_rows(&[vec![0.3]]), &[7.0], 1e-8).unwrap();
        let p = f.predict(&Matrix::from_rows(&[vec![0.9]]));
        assert!((p.pred[0] - 7.0).abs() < 1e-6);
    }
}
