//! Dense linear algebra substrate (f64, row-major).
//!
//! Backs the native GP surrogate (Cholesky + triangular solves), the
//! RBF interpolant, and the Ernest-style linear predictor (ridge least
//! squares). Sizes here are tiny (<= ~100), so clarity beats blocking;
//! the AOT/PJRT path owns the "big" math.

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut m = Matrix::zeros(rows.len(), cols);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), cols, "ragged rows");
            m.data[i * cols..(i + 1) * cols].copy_from_slice(r);
        }
        m
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Append one row in place (amortized O(cols)). A matrix created as
    /// `zeros(0, 0)` adopts the width of its first pushed row, so callers
    /// that learn the dimensionality from the data can still stream rows.
    pub fn push_row(&mut self, row: &[f64]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self * v (matrix-vector).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// self^T * v.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += a * vi;
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization A = L L^T of an SPD matrix. Returns None if a
/// non-positive pivot appears (caller should add jitter and retry).
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Count of [`solve_lower`] calls on this thread (debug builds only;
/// always 0 in release). Backs the whitened-cache tests asserting that
/// `predict_pinned` performs no per-candidate triangular solves. The
/// counter is thread-local so concurrently running tests cannot pollute
/// each other's deltas.
#[cfg(debug_assertions)]
thread_local! {
    static SOLVE_LOWER_CALLS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of [`solve_lower`] invocations performed by the current thread
/// (debug builds; release builds return 0 and pay no counting cost).
pub fn solve_lower_calls() -> u64 {
    #[cfg(debug_assertions)]
    {
        SOLVE_LOWER_CALLS.with(|c| c.get())
    }
    #[cfg(not(debug_assertions))]
    {
        0
    }
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    #[cfg(debug_assertions)]
    SOLVE_LOWER_CALLS.with(|c| c.set(c.get() + 1));
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve L V = B for an n×m right-hand side by blocked forward
/// substitution — one pass over B with contiguous row arithmetic, used to
/// (re)build whitened candidate matrices `V = L⁻¹ K(X, C)` wholesale.
/// Column j of the result is bit-identical to `solve_lower(l, column_j)`:
/// the per-entry operation order is the same, so incremental consumers
/// can mix this with row-at-a-time appends without drift.
pub fn solve_lower_multi(l: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(l.rows, l.cols);
    assert_eq!(b.rows, l.rows);
    let (n, m) = (b.rows, b.cols);
    let mut v = b.clone();
    for i in 0..n {
        let (done, rest) = v.data.split_at_mut(i * m);
        let row_i = &mut rest[..m];
        for k in 0..i {
            let lik = l[(i, k)];
            let row_k = &done[k * m..(k + 1) * m];
            for (vi, &vk) in row_i.iter_mut().zip(row_k) {
                *vi -= lik * vk;
            }
        }
        let d = l[(i, i)];
        for vi in row_i.iter_mut() {
            *vi /= d;
        }
    }
    v
}

/// Solve L^T x = b (back substitution), L lower-triangular.
pub fn solve_upper_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Rank-1 Cholesky append: given the factor L of an n×n SPD matrix A,
/// return the factor of the bordered matrix [[A, k], [kᵀ, d]] in O(n²)
/// (one triangular solve + copy) instead of the O(n³) full refactor.
/// Returns None when the new pivot is not positive (the bordered matrix
/// is not positive definite — caller should rebuild with jitter).
pub fn cholesky_append(l: &Matrix, k: &[f64], d: f64) -> Option<Matrix> {
    let n = l.rows;
    assert_eq!(l.rows, l.cols);
    assert_eq!(k.len(), n);
    let row = solve_lower(l, k);
    let pivot = d - row.iter().map(|v| v * v).sum::<f64>();
    if pivot <= 0.0 || !pivot.is_finite() {
        return None;
    }
    let mut out = Matrix::zeros(n + 1, n + 1);
    for i in 0..n {
        for j in 0..=i {
            out[(i, j)] = l[(i, j)];
        }
    }
    for (j, &v) in row.iter().enumerate() {
        out[(n, j)] = v;
    }
    out[(n, n)] = pivot.sqrt();
    Some(out)
}

/// Solve A x = b for SPD A via Cholesky with escalating jitter.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    let mut jitter = 0.0;
    for attempt in 0..6 {
        let mut aj = a.clone();
        if attempt > 0 {
            jitter = if jitter == 0.0 { 1e-10 } else { jitter * 100.0 };
            for i in 0..aj.rows {
                aj[(i, i)] += jitter;
            }
        }
        if let Some(l) = cholesky(&aj) {
            let y = solve_lower(&l, b);
            return Some(solve_upper_t(&l, &y));
        }
    }
    None
}

/// Solve a general square system A x = b by Gaussian elimination with
/// partial pivoting (for the RBF saddle system, which is indefinite).
pub fn solve_general(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    assert_eq!(b.len(), n);
    let mut m = a.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // Partial pivot.
        // total_cmp keeps pivot selection NaN-safe: a poisoned column
        // yields a non-finite pmax and a clean None instead of a panic.
        let (piv, pmax) = (col..n)
            .map(|r| (r, m[(r, col)].abs()))
            .max_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        if pmax < 1e-300 || !pmax.is_finite() {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let tmp = m[(col, j)];
                m[(col, j)] = m[(piv, j)];
                m[(piv, j)] = tmp;
            }
            x.swap(col, piv);
        }
        let d = m[(col, col)];
        for r in col + 1..n {
            let f = m[(r, col)] / d;
            if f == 0.0 {
                continue;
            }
            m[(r, col)] = 0.0;
            for j in col + 1..n {
                let v = m[(col, j)];
                m[(r, j)] -= f * v;
            }
            x[r] -= f * x[col];
        }
    }
    // Back substitution.
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in i + 1..n {
            s -= m[(i, j)] * x[j];
        }
        x[i] = s / m[(i, i)];
    }
    Some(x)
}

/// Ridge least squares: argmin ||X w - y||^2 + ridge ||w||^2, via normal
/// equations + SPD solve. X: [n, p], y: [n]. The normal matrix is
/// symmetric, so only the upper triangle is accumulated over the n
/// rows (halving the O(n·p²) build) and mirrored once at the end.
pub fn lstsq_ridge(x: &Matrix, y: &[f64], ridge: f64) -> Option<Vec<f64>> {
    assert_eq!(x.rows, y.len());
    let p = x.cols;
    let mut xtx = Matrix::zeros(p, p);
    for r in 0..x.rows {
        let row = x.row(r);
        for i in 0..p {
            let ri = row[i];
            for j in i..p {
                xtx[(i, j)] += ri * row[j];
            }
        }
    }
    for i in 0..p {
        xtx[(i, i)] += ridge;
        for j in i + 1..p {
            xtx[(j, i)] = xtx[(i, j)];
        }
    }
    let xty = x.matvec_t(y);
    solve_spd(&xtx, &xty)
}

/// Log-determinant of L L^T given L: 2 * sum(log diag L).
pub fn logdet_from_chol(l: &Matrix) -> f64 {
    (0..l.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
        }
        let at = a.transpose();
        let mut spd = at.matmul(&a);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random_spd(5, &mut rng);
        let i = Matrix::identity(5);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(2);
        let a = random_spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for i in 0..8 {
            for j in 0..8 {
                assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::identity(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_solve_residual() {
        let mut rng = Rng::new(3);
        let a = random_spd(10, &mut rng);
        let b: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let x = solve_spd(&a, &b).unwrap();
        let r = a.matvec(&x);
        for i in 0..10 {
            assert!((r[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn general_solve_with_pivoting() {
        // Needs pivoting: zero on the leading diagonal.
        let a = Matrix::from_rows(&[
            vec![0.0, 2.0, 1.0],
            vec![1.0, -2.0, -3.0],
            vec![-1.0, 1.0, 2.0],
        ]);
        let b = vec![-8.0, 0.0, 3.0];
        let x = solve_general(&a, &b).unwrap();
        let r = a.matvec(&x);
        for i in 0..3 {
            assert!((r[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn general_solve_singular_is_none() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve_general(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn lstsq_recovers_exact_fit() {
        // y = 3 + 2u over a few points, X = [1, u].
        let us = [0.0, 1.0, 2.0, 3.0];
        let x = Matrix::from_rows(&us.iter().map(|&u| vec![1.0, u]).collect::<Vec<_>>());
        let y: Vec<f64> = us.iter().map(|&u| 3.0 + 2.0 * u).collect();
        let w = lstsq_ridge(&x, &y, 1e-12).unwrap();
        assert!((w[0] - 3.0).abs() < 1e-6);
        assert!((w[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn triangular_solves_roundtrip() {
        let mut rng = Rng::new(4);
        let a = random_spd(6, &mut rng);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let y = solve_lower(&l, &b);
        let x = solve_upper_t(&l, &y);
        let r = a.matvec(&x);
        for i in 0..6 {
            assert!((r[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn cholesky_append_matches_full_factorization() {
        let mut rng = Rng::new(7);
        let a = random_spd(12, &mut rng);
        // Factor the leading 6x6 block, then append rows 6..12 one by one.
        let mut sub = Matrix::zeros(6, 6);
        for i in 0..6 {
            for j in 0..6 {
                sub[(i, j)] = a[(i, j)];
            }
        }
        let mut l = cholesky(&sub).unwrap();
        for m in 6..12 {
            let k: Vec<f64> = (0..m).map(|i| a[(m, i)]).collect();
            l = cholesky_append(&l, &k, a[(m, m)]).unwrap();
        }
        let full = cholesky(&a).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                assert!((l[(i, j)] - full[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_append_from_empty_and_reject_indefinite() {
        let empty = Matrix::zeros(0, 0);
        let l1 = cholesky_append(&empty, &[], 4.0).unwrap();
        assert_eq!(l1[(0, 0)], 2.0);
        // Appending a row that destroys positive definiteness fails.
        let l2 = cholesky_append(&l1, &[4.0], 1.0);
        assert!(l2.is_none());
    }

    #[test]
    fn push_row_grows_and_adopts_width() {
        let mut m = Matrix::zeros(0, 0);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!((m.rows, m.cols), (2, 3));
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        // Fixed-width empty matrices enforce their declared width.
        let mut f = Matrix::zeros(0, 2);
        f.push_row(&[7.0, 8.0]);
        assert_eq!(f[(0, 1)], 8.0);
    }

    #[test]
    #[should_panic(expected = "push_row width mismatch")]
    fn push_row_rejects_ragged_rows() {
        let mut m = Matrix::zeros(0, 2);
        m.push_row(&[1.0]);
    }

    /// Multi-RHS forward substitution must be *bit-identical* per column
    /// to the vector solve — the whitened-cache rebuild/append parity in
    /// the GP session rests on this.
    #[test]
    fn solve_lower_multi_matches_vector_solve_bitwise() {
        let mut rng = Rng::new(11);
        let a = random_spd(9, &mut rng);
        let l = cholesky(&a).unwrap();
        let mut b = Matrix::zeros(9, 5);
        for i in 0..9 {
            for j in 0..5 {
                b[(i, j)] = rng.normal();
            }
        }
        let v = solve_lower_multi(&l, &b);
        for j in 0..5 {
            let col: Vec<f64> = (0..9).map(|i| b[(i, j)]).collect();
            let want = solve_lower(&l, &col);
            for i in 0..9 {
                assert_eq!(v[(i, j)].to_bits(), want[i].to_bits(), "({i},{j})");
            }
        }
    }

    #[test]
    fn solve_lower_counter_counts_on_this_thread() {
        let mut rng = Rng::new(12);
        let a = random_spd(4, &mut rng);
        let l = cholesky(&a).unwrap();
        let before = solve_lower_calls();
        let _ = solve_lower(&l, &[1.0, 2.0, 3.0, 4.0]);
        let _ = solve_lower(&l, &[4.0, 3.0, 2.0, 1.0]);
        if cfg!(debug_assertions) {
            assert_eq!(solve_lower_calls() - before, 2);
        } else {
            assert_eq!(solve_lower_calls(), 0);
        }
    }

    #[test]
    fn logdet_matches_direct() {
        let a = Matrix::from_rows(&[vec![4.0, 0.0], vec![0.0, 9.0]]);
        let l = cholesky(&a).unwrap();
        assert!((logdet_from_chol(&l) - (36.0f64).ln()).abs() < 1e-12);
    }
}
