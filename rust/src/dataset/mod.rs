//! The offline benchmark dataset (paper §IV-A).
//!
//! The paper evaluates every optimizer *against a pre-collected offline
//! dataset* — when an algorithm asks for an evaluation, the measurement is
//! read from the store instead of deploying a Kubernetes cluster. This
//! module materializes exactly that: 30 workloads x 88 configurations x R
//! repeated measurements of (runtime, cost), generated deterministically
//! by the simulator, persisted as CSV, and exposed to optimizers through
//! [`objective::LookupObjective`].

pub mod objective;

use crate::domain::{Config, Domain};
use crate::simulator::tasks::{all_workloads, Workload};
use crate::simulator::{self};
use crate::util::csv;
use crate::util::rng::Rng;

/// Optimization target (paper: each workload yields two tasks).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Target {
    Time,
    Cost,
}

impl Target {
    pub fn name(self) -> &'static str {
        match self {
            Target::Time => "time",
            Target::Cost => "cost",
        }
    }

    pub fn parse(s: &str) -> Option<Target> {
        match s {
            "time" => Some(Target::Time),
            "cost" => Some(Target::Cost),
            _ => None,
        }
    }

    pub fn pick(self, (runtime, cost): (f64, f64)) -> f64 {
        match self {
            Target::Time => runtime,
            Target::Cost => cost,
        }
    }
}

pub const BOTH_TARGETS: [Target; 2] = [Target::Time, Target::Cost];

/// The materialized offline store.
pub struct OfflineDataset {
    pub domain: Domain,
    pub workloads: Vec<Workload>,
    pub reps: usize,
    /// data[workload][config_id][rep] = (runtime_s, cost_usd)
    data: Vec<Vec<Vec<(f64, f64)>>>,
    /// Source measurements performed by the *evaluation path*
    /// ([`objective::LookupObjective::measure`] bumps this) — the proxy
    /// for "cloud deployments performed". Ground-truth bookkeeping
    /// (`mean_value`/`true_min`) deliberately does not count: the
    /// serving tests assert that a response answered from the
    /// scheduler's cross-request cache adds zero reads.
    pub(crate) reads: std::sync::atomic::AtomicU64,
}

impl OfflineDataset {
    /// Generate the full dataset from the simulator, deterministically.
    pub fn generate(seed: u64, reps: usize) -> OfflineDataset {
        Self::generate_for(seed, reps, all_workloads())
    }

    /// The ML-inference workload suite (the paper's stated future work;
    /// see `simulator::tasks::INFERENCE_TASKS`). Same domain, same
    /// methodology, 10 workloads.
    pub fn generate_inference(seed: u64, reps: usize) -> OfflineDataset {
        Self::generate_for(seed, reps, crate::simulator::tasks::inference_workloads())
    }

    /// Generate for an explicit workload list.
    pub fn generate_for(seed: u64, reps: usize, workloads: Vec<Workload>) -> OfflineDataset {
        let domain = Domain::paper();
        let grid = domain.full_grid();
        let mut root = Rng::new(seed);
        let data = workloads
            .iter()
            .enumerate()
            .map(|(wi, w)| {
                let mut wrng = root.fork(wi as u64);
                grid.iter()
                    .map(|cfg| {
                        (0..reps).map(|_| simulator::measure(&domain, w, cfg, &mut wrng)).collect()
                    })
                    .collect()
            })
            .collect();
        OfflineDataset {
            domain,
            workloads,
            reps,
            data,
            reads: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn workload_count(&self) -> usize {
        self.workloads.len()
    }

    pub fn workload_index(&self, id: &str) -> Option<usize> {
        self.workloads.iter().position(|w| w.id() == id)
    }

    /// All repetitions for (workload, config).
    pub fn measurements(&self, workload: usize, config_id: usize) -> &[(f64, f64)] {
        &self.data[workload][config_id]
    }

    /// Evaluation-path source measurements performed since construction
    /// (see the `reads` field).
    pub fn measurement_reads(&self) -> u64 {
        self.reads.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Mean target value over repetitions (the "ground truth" used for
    /// regret and savings denominators).
    pub fn mean_value(&self, workload: usize, config_id: usize, target: Target) -> f64 {
        let ms = self.measurements(workload, config_id);
        ms.iter().map(|&m| target.pick(m)).sum::<f64>() / ms.len() as f64
    }

    /// True optimum of a workload/target over the whole grid (mean over
    /// reps), as (config_id, value).
    pub fn true_min(&self, workload: usize, target: Target) -> (usize, f64) {
        (0..self.domain.size())
            .map(|c| (c, self.mean_value(workload, c, target)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
    }

    /// Expected value of choosing a configuration uniformly at random
    /// (the savings baseline R_rand of §IV-E).
    pub fn random_strategy_value(&self, workload: usize, target: Target) -> f64 {
        let n = self.domain.size();
        (0..n).map(|c| self.mean_value(workload, c, target)).sum::<f64>() / n as f64
    }

    // -- CSV persistence ----------------------------------------------------

    pub fn to_csv(&self) -> String {
        let grid = self.domain.full_grid();
        let mut rows = vec![vec![
            "task".to_string(),
            "dataset".to_string(),
            "provider".to_string(),
            "params".to_string(),
            "nodes".to_string(),
            "rep".to_string(),
            "runtime_s".to_string(),
            "cost_usd".to_string(),
        ]];
        for (wi, w) in self.workloads.iter().enumerate() {
            for (ci, cfg) in grid.iter().enumerate() {
                let p = &self.domain.providers[cfg.provider];
                let params = p
                    .params
                    .iter()
                    .zip(&cfg.choices)
                    .map(|(def, &c)| format!("{}={}", def.name, def.values[c]))
                    .collect::<Vec<_>>()
                    .join(";");
                for (rep, m) in self.data[wi][ci].iter().enumerate() {
                    rows.push(vec![
                        w.task.name.to_string(),
                        w.dataset.name.to_string(),
                        p.name.to_string(),
                        params.clone(),
                        cfg.nodes.to_string(),
                        rep.to_string(),
                        format!("{:.6}", m.0),
                        format!("{:.8}", m.1),
                    ]);
                }
            }
        }
        csv::write_rows(&rows)
    }

    pub fn from_csv(text: &str) -> Result<OfflineDataset, String> {
        let table = csv::Table::parse(text)?;
        let domain = Domain::paper();
        let workloads = all_workloads();
        let n_cfg = domain.size();

        // Collect into (workload, config) -> Vec<(rep, runtime, cost)>.
        let mut cells: Vec<Vec<Vec<(usize, f64, f64)>>> =
            vec![vec![Vec::new(); n_cfg]; workloads.len()];
        for (ri, row) in table.rows.iter().enumerate() {
            let get = |name: &str| {
                table.get(row, name).ok_or_else(|| format!("row {}: missing {name}", ri + 2))
            };
            let wid = format!("{}:{}", get("task")?, get("dataset")?);
            let wi = workloads
                .iter()
                .position(|w| w.id() == wid)
                .ok_or_else(|| format!("row {}: unknown workload {wid}", ri + 2))?;
            let provider = domain
                .provider_index(get("provider")?)
                .ok_or_else(|| format!("row {}: unknown provider", ri + 2))?;
            let pspace = &domain.providers[provider];
            let mut choices = vec![usize::MAX; pspace.params.len()];
            for kv in get("params")?.split(';') {
                let (k, v) =
                    kv.split_once('=').ok_or_else(|| format!("row {}: bad params", ri + 2))?;
                let qi = pspace
                    .params
                    .iter()
                    .position(|q| q.name == k)
                    .ok_or_else(|| format!("row {}: unknown param {k}", ri + 2))?;
                choices[qi] = pspace.params[qi]
                    .values
                    .iter()
                    .position(|&val| val == v)
                    .ok_or_else(|| format!("row {}: unknown value {v} for {k}", ri + 2))?;
            }
            if choices.contains(&usize::MAX) {
                return Err(format!("row {}: incomplete params", ri + 2));
            }
            let nodes: u32 =
                get("nodes")?.parse().map_err(|_| format!("row {}: bad nodes", ri + 2))?;
            let cfg = Config { provider, choices, nodes };
            let ci = domain.config_id(&cfg);
            let rep: usize =
                get("rep")?.parse().map_err(|_| format!("row {}: bad rep", ri + 2))?;
            let rt: f64 =
                get("runtime_s")?.parse().map_err(|_| format!("row {}: bad runtime", ri + 2))?;
            let cost: f64 =
                get("cost_usd")?.parse().map_err(|_| format!("row {}: bad cost", ri + 2))?;
            cells[wi][ci].push((rep, rt, cost));
        }

        let mut reps = None;
        let mut data = Vec::with_capacity(workloads.len());
        for (wi, w) in workloads.iter().enumerate() {
            let mut per_cfg = Vec::with_capacity(n_cfg);
            for (ci, cell) in cells[wi].iter_mut().enumerate() {
                if cell.is_empty() {
                    return Err(format!("no measurements for {} config {ci}", w.id()));
                }
                cell.sort_by_key(|&(rep, _, _)| rep);
                let r = reps.get_or_insert(cell.len());
                if *r != cell.len() {
                    return Err(format!("inconsistent rep count for {}", w.id()));
                }
                per_cfg.push(cell.iter().map(|&(_, t, c)| (t, c)).collect());
            }
            data.push(per_cfg);
        }
        Ok(OfflineDataset {
            domain,
            workloads,
            reps: reps.unwrap_or(0),
            data,
            reads: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Load from a CSV file, or generate-and-save if the file is missing.
    pub fn load_or_generate(path: &str, seed: u64, reps: usize) -> Result<OfflineDataset, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::from_csv(&text),
            Err(_) => {
                let ds = Self::generate(seed, reps);
                if let Some(dir) = std::path::Path::new(path).parent() {
                    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                }
                std::fs::write(path, ds.to_csv()).map_err(|e| e.to_string())?;
                Ok(ds)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> OfflineDataset {
        OfflineDataset::generate(7, 3)
    }

    #[test]
    fn generation_is_deterministic() {
        let a = OfflineDataset::generate(42, 2);
        let b = OfflineDataset::generate(42, 2);
        assert_eq!(a.measurements(3, 10), b.measurements(3, 10));
        let c = OfflineDataset::generate(43, 2);
        assert_ne!(a.measurements(3, 10), c.measurements(3, 10));
    }

    #[test]
    fn shape_is_30x88xreps() {
        let ds = small();
        assert_eq!(ds.workload_count(), 30);
        assert_eq!(ds.domain.size(), 88);
        for w in 0..30 {
            for c in 0..88 {
                assert_eq!(ds.measurements(w, c).len(), 3);
            }
        }
    }

    #[test]
    fn csv_roundtrip_preserves_values() {
        let ds = small();
        let text = ds.to_csv();
        let back = OfflineDataset::from_csv(&text).unwrap();
        assert_eq!(back.reps, 3);
        for w in [0, 7, 29] {
            for c in [0, 24, 40, 87] {
                let a = ds.measurements(w, c);
                let b = back.measurements(w, c);
                for (x, y) in a.iter().zip(b) {
                    assert!((x.0 - y.0).abs() < 1e-4, "runtime {x:?} vs {y:?}");
                    assert!((x.1 - y.1).abs() < 1e-6, "cost {x:?} vs {y:?}");
                }
            }
        }
    }

    #[test]
    fn true_min_is_really_minimal() {
        let ds = small();
        for target in BOTH_TARGETS {
            let (cid, v) = ds.true_min(0, target);
            for c in 0..ds.domain.size() {
                assert!(ds.mean_value(0, c, target) >= v - 1e-12);
            }
            assert!(cid < ds.domain.size());
        }
    }

    #[test]
    fn random_strategy_value_between_min_and_max() {
        let ds = small();
        for w in [2, 15] {
            for target in BOTH_TARGETS {
                let r = ds.random_strategy_value(w, target);
                let (_, mn) = ds.true_min(w, target);
                assert!(r > mn);
            }
        }
    }

    #[test]
    fn from_csv_rejects_corrupt_input() {
        assert!(OfflineDataset::from_csv("task,dataset\nx,y\n").is_err());
        let ds = small();
        let text = ds.to_csv();
        // Drop a row: incomplete grid must be rejected.
        let mut lines: Vec<&str> = text.lines().collect();
        lines.remove(1);
        let partial = lines.join("\n");
        assert!(OfflineDataset::from_csv(&partial).is_err());
    }
}
