//! The black-box objective f_k(n, x) (paper §III-A) and the evaluation
//! ledger every optimizer runs against.
//!
//! Three layers:
//!
//! * [`EvalSource`] / [`LookupObjective`] — the raw measurement source:
//!   map a configuration to one observed scalar, backed by the offline
//!   store. Measurement is `&self` and thread-safe: a `SingleDraw` is
//!   derived from a per-(configuration, pull-index) seeded stream, so the
//!   value of "the k-th measurement of config c" is a pure function of
//!   (source seed, c, k) — independent of global evaluation order and of
//!   which thread performs it.
//! * [`EvalLedger`] — the single evaluation substrate shared by the whole
//!   optimizer suite. It owns history recording, best-so-far tracing,
//!   search-expense accounting (the C_opt term of the §IV-E savings
//!   analysis), **hard budget enforcement** (an optimizer physically
//!   cannot overspend: `eval` refuses once the budget is gone), and
//!   opt-in memoization for deterministic measure modes. The budget lives
//!   in an atomic [`BudgetPool`], so enforcement survives concurrency.
//! * [`LedgerShard`] — a per-arm slice of a ledger for parallel arm
//!   execution (CloudBandit / Rising Bandits). Shards reserve budget from
//!   the parent's shared atomic pool, record locally, and are merged back
//!   deterministically in the caller's canonical (round, arm, pull) order
//!   regardless of thread completion order.
//!
//! Optimizers never see the source directly — they only hold a ledger (or
//! a shard of one, behind [`EvalSink`]), so per-optimizer history/budget
//! bookkeeping cannot drift and the coordinator reads expense/evals/trace
//! from one place.
//!
//! Determinism contract for shards: for non-deterministic sources
//! (`SingleDraw`) arms must evaluate **disjoint** configuration subsets
//! (true by construction for per-provider arms). For deterministic,
//! memoized sources the contract extends to **overlapping** arms: the
//! memo is one concurrent map shared by the ledger and every shard
//! (checked at record time, so concurrent arms share measurements), and
//! expense charging is decided at merge time in the caller's canonical
//! order — never by which thread measured first — so sequential and
//! parallel execution produce bit-identical merged ledgers either way.
//! The budget cap holds unconditionally in all modes.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::{OfflineDataset, Target};
use crate::domain::Config;
use crate::util::cancel::CancelToken;
use crate::util::rng::{splitmix64, Rng};

/// How one evaluation aggregates the stored repetitions (paper §III-A:
/// "a single measurement or any chosen metric based on multiple
/// measurements, such as the mean or the 90th percentile").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MeasureMode {
    /// One stored repetition chosen per evaluation from a seeded
    /// per-(config, pull) stream (the paper's default online behaviour).
    SingleDraw,
    Mean,
    P90,
}

impl MeasureMode {
    /// Whether repeated evaluations of one configuration always return
    /// the same value. Only deterministic modes may be memoized.
    pub fn deterministic(self) -> bool {
        !matches!(self, MeasureMode::SingleDraw)
    }

    pub fn name(self) -> &'static str {
        match self {
            MeasureMode::SingleDraw => "single_draw",
            MeasureMode::Mean => "mean",
            MeasureMode::P90 => "p90",
        }
    }

    pub fn parse(s: &str) -> Option<MeasureMode> {
        match s {
            "single_draw" | "single-draw" => Some(MeasureMode::SingleDraw),
            "mean" => Some(MeasureMode::Mean),
            "p90" => Some(MeasureMode::P90),
            _ => None,
        }
    }
}

/// A raw measurement source: one configuration in, one observed scalar
/// out. Implementations do **no** bookkeeping — that is the ledger's job.
///
/// `measure` is `&self` and must be thread-safe (`Sync`): ledger shards
/// running on worker threads share one source. `pull` is the 0-based
/// count of prior measurements of this configuration; a source whose
/// repeat measurements differ (e.g. `SingleDraw`) must derive them from
/// (seed, cfg, pull) only, never from call order.
pub trait EvalSource: Sync {
    fn measure(&self, cfg: &Config, pull: u64) -> f64;

    /// True when repeated measurements of the same configuration are
    /// identical; gates [`EvalLedger::with_memo`].
    fn deterministic(&self) -> bool {
        false
    }
}

/// Offline-store-backed measurement source for one (workload, target)
/// task.
pub struct LookupObjective<'a> {
    ds: &'a OfflineDataset,
    pub workload: usize,
    pub target: Target,
    pub mode: MeasureMode,
    seed: u64,
    /// `(market_seed, tick)` when this source measures under a dynamic
    /// market: cost values are scaled by the provider's effective price
    /// at that tick. `None` (the default) is the static dataset.
    market: Option<(u64, u64)>,
}

impl<'a> LookupObjective<'a> {
    pub fn new(
        ds: &'a OfflineDataset,
        workload: usize,
        target: Target,
        mode: MeasureMode,
        seed: u64,
    ) -> Self {
        assert!(workload < ds.workload_count());
        LookupObjective { ds, workload, target, mode, seed, market: None }
    }

    /// Measure under the dynamic market at `(market_seed, tick)`: cost
    /// values gain the provider's price drift + spot discount at that
    /// tick (runtimes are market-independent — a price move does not
    /// change how fast a machine is). Still a pure function of
    /// `(seed, cfg, pull, market_seed, tick)` — clock-free.
    pub fn with_market(mut self, market_seed: u64, tick: u64) -> Self {
        self.market = Some((market_seed, tick));
        self
    }

    pub fn domain(&self) -> &crate::domain::Domain {
        &self.ds.domain
    }

    /// Market multiplier applied to this source's values for `cfg`:
    /// the provider's effective price when targeting cost under a
    /// market, 1.0 otherwise.
    fn market_factor(&self, cfg: &Config) -> f64 {
        match self.market {
            Some((market_seed, tick)) if self.target == Target::Cost => {
                crate::simulator::market::effective_price(market_seed, cfg.provider, tick)
            }
            _ => 1.0,
        }
    }

    /// Peek at the mean value without going through a ledger (used by
    /// tests and the savings analysis to price the *returned*
    /// configuration by its ground truth). Under a market, the same
    /// per-tick price scaling as [`EvalSource::measure`] applies.
    pub fn ground_truth(&self, cfg: &Config) -> f64 {
        let cid = self.ds.domain.config_id(cfg);
        self.ds.mean_value(self.workload, cid, self.target) * self.market_factor(cfg)
    }
}

impl EvalSource for LookupObjective<'_> {
    fn measure(&self, cfg: &Config, pull: u64) -> f64 {
        // One evaluation = one source measurement (the "cloud
        // deployment" proxy the serving cache tests count). Ground-truth
        // bookkeeping reads the store without passing through here.
        self.ds.reads.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let cid = self.ds.domain.config_id(cfg);
        let ms = self.ds.measurements(self.workload, cid);
        let value = match self.mode {
            MeasureMode::SingleDraw => {
                // Per-(config, pull) stream: two SplitMix64 rounds mix the
                // config id and the pull index into the source seed, so
                // the draw is decorrelated across both axes yet a pure
                // function of (seed, cid, pull).
                let mut s = self.seed ^ (cid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let mut s2 = splitmix64(&mut s) ^ pull.wrapping_mul(0xD1B5_4A32_D192_ED03);
                let mut rng = Rng::new(splitmix64(&mut s2));
                self.target.pick(ms[rng.usize_below(ms.len())])
            }
            MeasureMode::Mean => {
                ms.iter().map(|&m| self.target.pick(m)).sum::<f64>() / ms.len() as f64
            }
            MeasureMode::P90 => {
                let vals: Vec<f64> = ms.iter().map(|&m| self.target.pick(m)).collect();
                crate::util::stats::percentile(&vals, 90.0)
            }
        };
        value * self.market_factor(cfg)
    }

    fn deterministic(&self) -> bool {
        self.mode.deterministic()
    }
}

/// Shared atomic evaluation budget: the single admission point for a
/// ledger and all of its shards. A reservation that succeeds here is the
/// *only* way an evaluation happens, so concurrent shards cannot
/// collectively over-admit past the trial budget.
#[derive(Debug)]
pub struct BudgetPool {
    remaining: AtomicUsize,
    /// Reservations granted so far (monotone). Cancellation is only
    /// honored once at least one pull has been granted, so a cancelled
    /// trial still produces a non-empty ledger (see
    /// [`EvalLedger::with_cancel`]).
    granted: AtomicUsize,
}

impl BudgetPool {
    fn new(budget: usize) -> BudgetPool {
        BudgetPool { remaining: AtomicUsize::new(budget), granted: AtomicUsize::new(0) }
    }

    /// Evaluations still admissible.
    pub fn remaining(&self) -> usize {
        self.remaining.load(Ordering::Acquire)
    }

    /// Evaluations granted so far across the ledger and all shards.
    pub fn granted(&self) -> usize {
        self.granted.load(Ordering::Acquire)
    }

    /// Reserve one evaluation; `false` once the pool is empty.
    pub fn try_reserve(&self) -> bool {
        let mut cur = self.remaining.load(Ordering::Acquire);
        while cur > 0 {
            match self.remaining.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    self.granted.fetch_add(1, Ordering::AcqRel);
                    return true;
                }
                Err(observed) => cur = observed,
            }
        }
        false
    }
}

/// Whether a cancel token should stop the next pull. Cancellation is
/// checked **between pulls** and honored only after at least one global
/// grant, so (a) completed evaluations are never altered — the prefix
/// stays bit-identical to an uncancelled run — and (b) a cancelled trial
/// still performs its first pull, keeping every downstream consumer's
/// non-empty-ledger invariant intact.
fn cancel_requested(cancel: &Option<CancelToken>, pool: &BudgetPool) -> bool {
    match cancel {
        Some(token) => pool.granted() > 0 && token.is_cancelled(),
        None => false,
    }
}

/// The budget-gated evaluation interface optimizer *search states* step
/// against: a whole [`EvalLedger`] or one [`LedgerShard`] of it.
pub trait EvalSink {
    /// Evaluate a configuration, consuming one unit of budget; `None`
    /// (performing no measurement) once the budget is exhausted.
    fn eval(&mut self, cfg: &Config) -> Option<f64>;

    /// Whether the next `eval` is guaranteed to return `None`.
    fn exhausted(&self) -> bool;
}

/// One evaluation performed against a shard, staged for merge.
struct ShardRecord {
    cfg: Config,
    value: f64,
    /// Whether this shard actually performed a source measurement (false
    /// on a memo hit). For memoized ledgers the *charge* is re-decided
    /// canonically at merge time; this flag only feeds the non-memoized
    /// path, where it is always true.
    fresh: bool,
}

/// The measurement memo shared by a ledger and all of its shards: one
/// concurrent map, so overlapping arms running on different threads
/// reuse each other's measurements instead of re-reading the source.
type SharedMemo = Arc<Mutex<HashMap<Config, f64>>>;

fn measure_next(
    source: &dyn EvalSource,
    pulls: &mut HashMap<Config, u64>,
    memo: &Option<SharedMemo>,
    cfg: &Config,
) -> (f64, bool) {
    let mut draw = |pulls: &mut HashMap<Config, u64>| {
        let count = pulls.entry(cfg.clone()).or_insert(0);
        let v = source.measure(cfg, *count);
        *count += 1;
        v
    };
    match memo {
        Some(memo) => {
            // Checked (and populated) under the lock at record time, so a
            // configuration is measured at most once across all shards.
            let mut memo = memo.lock().unwrap();
            match memo.get(cfg) {
                Some(&v) => (v, false),
                None => {
                    let v = draw(pulls);
                    memo.insert(cfg.clone(), v);
                    (v, true)
                }
            }
        }
        None => (draw(pulls), true),
    }
}

/// Budget-enforcing evaluation ledger: the only handle optimizers get.
pub struct EvalLedger<'a> {
    source: &'a dyn EvalSource,
    budget: usize,
    pool: Arc<BudgetPool>,
    history: Vec<(Config, f64)>,
    /// Best-so-far observed value after each evaluation.
    trace: Vec<f64>,
    /// Index into `history` of the best observation so far.
    best_idx: Option<usize>,
    /// Sum of the target metric over every *charged* evaluation (memo
    /// hits are free: the measurement was already paid for).
    expense: f64,
    /// Shared concurrent memo (deterministic measure modes only): one map
    /// for the ledger and every shard split off it.
    memo: Option<SharedMemo>,
    /// Canonical charging record for memoized ledgers: configurations
    /// whose first (charged) appearance has been recorded. Kept on the
    /// parent only and consulted in record/merge order, so C_opt is a
    /// pure function of the merged history — never of which thread
    /// happened to measure a configuration first.
    charged_cfgs: Option<HashSet<Config>>,
    /// Per-configuration pull counts driving [`EvalSource::measure`].
    pulls: HashMap<Config, u64>,
    /// Optional cooperative cancellation, checked between pulls (see
    /// [`cancel_requested`]). Shared with every shard split off this
    /// ledger.
    cancel: Option<CancelToken>,
}

impl<'a> EvalLedger<'a> {
    /// A ledger with a hard evaluation cap. There is deliberately no
    /// "unlimited" constructor: every optimizer loop runs to budget
    /// exhaustion, so an uncapped ledger would never terminate — callers
    /// with a fixed known cost (the predictive baselines) size the
    /// budget to exactly that cost instead.
    pub fn new(source: &'a dyn EvalSource, budget: usize) -> Self {
        EvalLedger {
            source,
            budget,
            pool: Arc::new(BudgetPool::new(budget)),
            history: Vec::new(),
            trace: Vec::new(),
            best_idx: None,
            expense: 0.0,
            memo: None,
            charged_cfgs: None,
            pulls: HashMap::new(),
            cancel: None,
        }
    }

    /// Attach a cooperative cancellation token. The ledger (and every
    /// shard split off it) checks the token **between pulls**: once it
    /// fires, the next `eval` returns `None` exactly as if the budget
    /// were exhausted, so optimizer step loops stop without any new code
    /// paths. The first pull is always honored (see [`cancel_requested`])
    /// and completed work is never altered — a cancelled run's history is
    /// bit-identical to the uncancelled run's prefix.
    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Enable memoization: repeated evaluations of one configuration
    /// replay the recorded value (still consuming budget and appearing in
    /// the history) without being charged as expense again.
    ///
    /// Panics for non-deterministic sources — `MeasureMode::SingleDraw`
    /// legitimately re-draws on repeat evaluations, so caching would
    /// change the objective's semantics.
    pub fn with_memo(mut self) -> Self {
        assert!(
            self.source.deterministic(),
            "memoization requires a deterministic measure mode (Mean/P90)"
        );
        self.memo = Some(Arc::new(Mutex::new(HashMap::new())));
        self.charged_cfgs = Some(HashSet::new());
        self
    }

    /// The evaluation cap this ledger enforces.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Evaluations still available in the shared pool (counts budget
    /// reserved by outstanding shards as spent).
    pub fn remaining(&self) -> usize {
        self.pool.remaining()
    }

    pub fn exhausted(&self) -> bool {
        self.pool.remaining() == 0 || cancel_requested(&self.cancel, &self.pool)
    }

    /// Budget pulls the attached cancel token saved: the evaluations that
    /// were still admissible when cancellation stopped the run. Zero for
    /// uncancelled (or budget-exhausted-first) runs.
    pub fn pulls_saved(&self) -> usize {
        if cancel_requested(&self.cancel, &self.pool) {
            self.pool.remaining()
        } else {
            0
        }
    }

    /// Why this run stopped early, as the wire-visible reason string —
    /// `None` when the run completed normally. A token that fired only
    /// *after* the budget was already exhausted does not count: the run
    /// did all its work, so the result is the complete (cacheable) one.
    pub fn cancelled(&self) -> Option<&'static str> {
        if self.pool.remaining() > 0 && cancel_requested(&self.cancel, &self.pool) {
            self.cancel.as_ref().and_then(|t| t.reason()).map(|r| r.as_str())
        } else {
            None
        }
    }

    /// Append one evaluation outcome to history/trace/best/expense.
    ///
    /// `fresh` is whether a real source measurement backed this record.
    /// For memoized ledgers the charge is decided *here*, against the
    /// parent's canonical charged-set — the first record of a
    /// configuration (in record/merge order) pays, every later one is
    /// free — so expense is identical however work was scheduled.
    fn record(&mut self, cfg: Config, v: f64, fresh: bool) {
        let charged = match &mut self.charged_cfgs {
            Some(set) => set.insert(cfg.clone()),
            None => fresh,
        };
        if charged {
            self.expense += v;
        }
        let best = self.trace.last().copied().unwrap_or(f64::INFINITY);
        if v < best {
            self.best_idx = Some(self.history.len());
        }
        self.trace.push(best.min(v));
        self.history.push((cfg, v));
    }

    /// Evaluate a configuration, consuming one unit of budget. Returns
    /// `None` — performing no measurement — once the budget is exhausted;
    /// the ledger is the budget's enforcement point, not a convention.
    pub fn eval(&mut self, cfg: &Config) -> Option<f64> {
        if cancel_requested(&self.cancel, &self.pool) || !self.pool.try_reserve() {
            return None;
        }
        let (v, fresh) = measure_next(self.source, &mut self.pulls, &self.memo, cfg);
        self.record(cfg.clone(), v, fresh);
        Some(v)
    }

    /// Evaluate, panicking on an exhausted budget. For callers with a
    /// fixed, known evaluation count that sized the ledger themselves
    /// (the predictive baselines); search loops should use
    /// [`eval`](Self::eval) and stop on `None`.
    pub fn must_eval(&mut self, cfg: &Config) -> f64 {
        self.eval(cfg).expect("evaluation budget exhausted")
    }

    /// Split off `n` shards for parallel arm execution. Each shard draws
    /// from this ledger's shared atomic [`BudgetPool`] (so shards plus
    /// parent can never over-admit collectively) and additionally carries
    /// a local allowance of `per_shard_budget` evaluations, extensible
    /// per round via [`LedgerShard::grant`].
    ///
    /// Shards inherit the parent's pull counters at split time and
    /// *share* the parent's memo map (if enabled): a configuration
    /// measured by any shard — or by the parent — is a memo hit for
    /// everyone else from that moment on, even mid-round. Merge folds
    /// pull counters back. Determinism requires disjoint configuration
    /// subsets only for non-deterministic sources (see module docs);
    /// memoized shards may overlap freely.
    pub fn shard(&self, n: usize, per_shard_budget: usize) -> Vec<LedgerShard<'a>> {
        (0..n)
            .map(|_| LedgerShard {
                source: self.source,
                pool: Arc::clone(&self.pool),
                allowance: per_shard_budget,
                records: Vec::new(),
                pulls: self.pulls.clone(),
                memo: self.memo.clone(),
                cancel: self.cancel.clone(),
            })
            .collect()
    }

    /// Drain one shard's staged records into this ledger, in the shard's
    /// local (pull) order. Callers merge shards in canonical arm order
    /// once per round, so the reassembled history/trace/expense/best is
    /// identical regardless of which thread finished first — for
    /// memoized ledgers the charge of each record is reconciled here
    /// against the canonical charged-set, not taken from the racy
    /// measured-it-first flag. Budget was already reserved at evaluation
    /// time; merging never re-charges it.
    pub fn merge(&mut self, shard: &mut LedgerShard<'_>) {
        for rec in shard.records.drain(..) {
            self.record(rec.cfg, rec.value, rec.fresh);
        }
        for (cfg, n) in &shard.pulls {
            let count = self.pulls.entry(cfg.clone()).or_insert(0);
            *count = (*count).max(*n);
        }
    }

    /// [`merge`](Self::merge) every shard in slice order.
    pub fn merge_all(&mut self, shards: &mut [LedgerShard<'_>]) {
        for s in shards {
            self.merge(s);
        }
    }

    /// Number of evaluations performed so far.
    pub fn evals(&self) -> usize {
        self.history.len()
    }

    /// Full evaluation log in order.
    pub fn history(&self) -> &[(Config, f64)] {
        &self.history
    }

    /// Best-so-far observed value after each evaluation.
    pub fn trace(&self) -> &[f64] {
        &self.trace
    }

    /// Total search expense (sum of the target metric over every charged
    /// evaluation) — the C_opt term of the §IV-E savings analysis. For
    /// the time target this is seconds spent; for cost, dollars spent.
    pub fn total_expense(&self) -> f64 {
        self.expense
    }

    /// Best (config, observed value) seen so far.
    pub fn best(&self) -> Option<(&Config, f64)> {
        self.best_idx.map(|i| (&self.history[i].0, self.history[i].1))
    }
}

impl EvalSink for EvalLedger<'_> {
    fn eval(&mut self, cfg: &Config) -> Option<f64> {
        EvalLedger::eval(self, cfg)
    }

    fn exhausted(&self) -> bool {
        EvalLedger::exhausted(self)
    }
}

/// One arm's slice of an [`EvalLedger`]: evaluates against the shared
/// atomic budget pool, records locally in pull order, and hands its
/// records back through [`EvalLedger::merge`]. `Send`, so arms can run on
/// worker threads while the parent ledger stays on the caller's thread.
pub struct LedgerShard<'a> {
    source: &'a dyn EvalSource,
    pool: Arc<BudgetPool>,
    /// Local cap: evaluations this shard may still admit (on top of the
    /// shared pool's global cap).
    allowance: usize,
    /// Evaluations since the last merge, in local order.
    records: Vec<ShardRecord>,
    pulls: HashMap<Config, u64>,
    /// Shared with the parent ledger and sibling shards (see
    /// [`EvalLedger::shard`]).
    memo: Option<SharedMemo>,
    /// Inherited from the parent ledger: all shards observe the same
    /// token, so one disconnect stops every arm at its next pull.
    cancel: Option<CancelToken>,
}

impl LedgerShard<'_> {
    /// Raise this shard's local allowance (e.g. a bandit round's pull
    /// quota). The shared pool still caps globally: granting more than
    /// the pool holds can never over-admit.
    pub fn grant(&mut self, extra: usize) {
        self.allowance = self.allowance.saturating_add(extra);
    }

    /// Local evaluations still admissible (ignoring the shared pool).
    pub fn allowance(&self) -> usize {
        self.allowance
    }

    /// Shared-pool view (same pool as the parent ledger and sibling
    /// shards).
    pub fn pool_remaining(&self) -> usize {
        self.pool.remaining()
    }

    /// Records staged for the next merge.
    pub fn pending(&self) -> usize {
        self.records.len()
    }
}

impl EvalSink for LedgerShard<'_> {
    fn eval(&mut self, cfg: &Config) -> Option<f64> {
        if self.allowance == 0
            || cancel_requested(&self.cancel, &self.pool)
            || !self.pool.try_reserve()
        {
            return None;
        }
        self.allowance -= 1;
        let (v, fresh) = measure_next(self.source, &mut self.pulls, &self.memo, cfg);
        self.records.push(ShardRecord { cfg: cfg.clone(), value: v, fresh });
        Some(v)
    }

    fn exhausted(&self) -> bool {
        self.allowance == 0
            || self.pool.remaining() == 0
            || cancel_requested(&self.cancel, &self.pool)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::Config;

    fn ds() -> OfflineDataset {
        OfflineDataset::generate(1, 5)
    }

    fn some_cfg() -> Config {
        Config { provider: 0, choices: vec![1, 0], nodes: 3 }
    }

    #[test]
    fn eval_consumes_budget_and_records_history() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let mut led = EvalLedger::new(&src, 4);
        assert_eq!(led.evals(), 0);
        assert_eq!(led.remaining(), 4);
        let v = led.eval(&some_cfg()).unwrap();
        assert!(v > 0.0);
        assert_eq!(led.evals(), 1);
        assert_eq!(led.history()[0].1, v);
        assert_eq!(led.trace(), &[v]);
        assert_eq!(led.total_expense(), v);
    }

    #[test]
    fn budget_is_physically_enforced() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let mut led = EvalLedger::new(&src, 3);
        for _ in 0..3 {
            assert!(led.eval(&some_cfg()).is_some());
        }
        assert!(led.exhausted());
        // Trying harder does not help: no measurement happens.
        for _ in 0..10 {
            assert!(led.eval(&some_cfg()).is_none());
        }
        assert_eq!(led.evals(), 3);
        assert_eq!(led.remaining(), 0);
    }

    #[test]
    fn mean_mode_is_deterministic() {
        let ds = ds();
        let a = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 1);
        let b = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 999);
        assert_eq!(a.measure(&some_cfg(), 0), b.measure(&some_cfg(), 7));
        assert!(a.deterministic());
    }

    #[test]
    fn market_scales_cost_by_effective_price_and_leaves_time_alone() {
        let ds = ds();
        let cost = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let cost_t0 = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9)
            .with_market(7, 0);
        let cost_t5 = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9)
            .with_market(7, 5);
        let time = LookupObjective::new(&ds, 0, Target::Time, MeasureMode::Mean, 9);
        let time_t5 = LookupObjective::new(&ds, 0, Target::Time, MeasureMode::Mean, 9)
            .with_market(7, 5);
        let mut moved = 0;
        for cfg in ds.domain.full_grid() {
            // Tick 0 is neutral: identical to the static dataset.
            assert_eq!(cost_t0.measure(&cfg, 0), cost.measure(&cfg, 0));
            let f = crate::simulator::market::effective_price(7, cfg.provider, 5);
            assert_eq!(cost_t5.measure(&cfg, 0), cost.measure(&cfg, 0) * f);
            assert_eq!(cost_t5.ground_truth(&cfg), cost.ground_truth(&cfg) * f);
            // Prices move; machine speed does not.
            assert_eq!(time_t5.measure(&cfg, 0), time.measure(&cfg, 0));
            if f != 1.0 {
                moved += 1;
            }
        }
        assert!(moved > 0, "tick 5 must reprice at least one provider");
    }

    #[test]
    fn market_single_draw_is_pure_in_seed_config_pull_and_tick() {
        let ds = ds();
        let cfg = some_cfg();
        let a = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 5)
            .with_market(11, 3);
        let b = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 5)
            .with_market(11, 3);
        for pull in 0..4 {
            assert_eq!(a.measure(&cfg, pull), b.measure(&cfg, pull));
        }
        assert!(!a.deterministic(), "single-draw stays non-memoizable under a market");
    }

    #[test]
    fn single_draw_depends_on_seed_but_stays_in_range() {
        let ds = ds();
        let cfg = some_cfg();
        let cid = ds.domain.config_id(&cfg);
        let vals: Vec<f64> =
            ds.measurements(2, cid).iter().map(|&m| Target::Time.pick(m)).collect();
        let (lo, hi) = (crate::util::stats::min(&vals), crate::util::stats::max(&vals));
        for seed in 0..20 {
            let o = LookupObjective::new(&ds, 2, Target::Time, MeasureMode::SingleDraw, seed);
            assert!(!o.deterministic());
            let v = o.measure(&cfg, 0);
            assert!(v >= lo && v <= hi);
        }
    }

    /// The measurement of (config, pull) is a pure function of the source
    /// seed — independent of call order, call count, and thread.
    #[test]
    fn single_draw_is_order_independent() {
        let ds = ds();
        let o = LookupObjective::new(&ds, 2, Target::Time, MeasureMode::SingleDraw, 5);
        let a = some_cfg();
        let b = Config { provider: 1, choices: vec![0, 1, 0], nodes: 5 };
        // Forward order.
        let fwd: Vec<f64> =
            vec![o.measure(&a, 0), o.measure(&a, 1), o.measure(&b, 0), o.measure(&b, 1)];
        // Interleaved/reversed order, fresh source, same seed.
        let o2 = LookupObjective::new(&ds, 2, Target::Time, MeasureMode::SingleDraw, 5);
        let rev: Vec<f64> =
            vec![o2.measure(&b, 1), o2.measure(&a, 1), o2.measure(&b, 0), o2.measure(&a, 0)];
        assert_eq!(fwd[0], rev[3]);
        assert_eq!(fwd[1], rev[1]);
        assert_eq!(fwd[2], rev[2]);
        assert_eq!(fwd[3], rev[0]);
        // Different pulls of one config are decorrelated draws: across
        // many pulls we must see more than one stored repetition.
        let distinct: std::collections::HashSet<u64> =
            (0..32).map(|p| o.measure(&a, p).to_bits()).collect();
        assert!(distinct.len() > 1, "pull index never changed the draw");
    }

    #[test]
    fn p90_at_least_median() {
        let ds = ds();
        let p90 = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::P90, 1);
        let mean = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::Mean, 1);
        let cfg = some_cfg();
        assert!(p90.measure(&cfg, 0) >= mean.measure(&cfg, 0) * 0.9);
    }

    #[test]
    fn best_and_trace_track_minimum() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 3);
        let grid = ds.domain.full_grid();
        let mut led = EvalLedger::new(&src, 10);
        for c in grid.iter().take(10) {
            led.eval(c);
        }
        let (bc, bv) = led.best().unwrap();
        assert!(led.history().iter().all(|(_, v)| *v >= bv));
        assert_eq!(*led.trace().last().unwrap(), bv);
        assert!(led.trace().windows(2).all(|w| w[1] <= w[0]));
        let bc = bc.clone();
        drop(led);
        assert_eq!(src.ground_truth(&bc), bv); // Mean mode = ground truth
    }

    #[test]
    fn memo_hits_replay_value_and_consume_budget_but_not_expense() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 1);
        let mut led = EvalLedger::new(&src, 5).with_memo();
        let cfg = some_cfg();
        let v1 = led.eval(&cfg).unwrap();
        let v2 = led.eval(&cfg).unwrap();
        assert_eq!(v1, v2);
        assert_eq!(led.evals(), 2, "memo hits still consume budget");
        assert_eq!(led.total_expense(), v1, "memo hits are not re-charged");
        assert_eq!(led.history().len(), 2, "memo hits still appear in history");
    }

    #[test]
    #[should_panic(expected = "memoization requires a deterministic measure mode")]
    fn memo_refused_for_single_draw() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 1);
        let _ = EvalLedger::new(&src, 5).with_memo();
    }

    #[test]
    #[should_panic(expected = "evaluation budget exhausted")]
    fn must_eval_panics_rather_than_overspending() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 1);
        let mut led = EvalLedger::new(&src, 1);
        led.must_eval(&some_cfg());
        led.must_eval(&some_cfg());
    }

    #[test]
    fn measure_mode_parse_roundtrip() {
        for mode in [MeasureMode::SingleDraw, MeasureMode::Mean, MeasureMode::P90] {
            assert_eq!(MeasureMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(MeasureMode::parse("single-draw"), Some(MeasureMode::SingleDraw));
        assert_eq!(MeasureMode::parse("median"), None);
    }

    // -- shard layer --------------------------------------------------------

    /// Distinct configs of distinct providers (the bandit disjointness
    /// contract).
    fn provider_cfg(p: usize) -> Config {
        let d = crate::domain::Domain::paper();
        d.provider_grid(p)[0].clone()
    }

    #[test]
    fn shards_share_the_pool_and_merge_in_canonical_order() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 1, Target::Cost, MeasureMode::SingleDraw, 7);
        let mut led = EvalLedger::new(&src, 10);
        let mut shards = led.shard(2, 0);
        assert_eq!(shards.len(), 2);
        // No allowance granted yet: shards refuse.
        assert!(shards[0].eval(&provider_cfg(0)).is_none());
        shards[0].grant(2);
        shards[1].grant(2);
        // Interleave evaluations "out of order" across shards.
        let b0 = shards[1].eval(&provider_cfg(1)).unwrap();
        let a0 = shards[0].eval(&provider_cfg(0)).unwrap();
        let b1 = shards[1].eval(&provider_cfg(1)).unwrap();
        let a1 = shards[0].eval(&provider_cfg(0)).unwrap();
        assert_eq!(led.remaining(), 6, "shard reservations hit the shared pool");
        assert_eq!(led.evals(), 0, "nothing merged yet");
        led.merge_all(&mut shards);
        // Canonical order: shard 0's pulls, then shard 1's.
        let vals: Vec<f64> = led.history().iter().map(|(_, v)| *v).collect();
        assert_eq!(vals, vec![a0, a1, b0, b1]);
        assert_eq!(led.evals(), 4);
        assert_eq!(led.total_expense(), a0 + a1 + b0 + b1);
        assert!(shards.iter().all(|s| s.pending() == 0), "merge drains records");
    }

    /// The same (config, pull) measured through a shard equals the value
    /// the parent ledger would have measured: shard execution cannot
    /// change measurement semantics.
    #[test]
    fn shard_measurements_match_direct_ledger_measurements() {
        let ds = ds();
        let cfg = provider_cfg(0);
        let src = LookupObjective::new(&ds, 4, Target::Cost, MeasureMode::SingleDraw, 13);
        let mut direct = EvalLedger::new(&src, 4);
        let direct_vals: Vec<f64> = (0..4).map(|_| direct.eval(&cfg).unwrap()).collect();

        let src2 = LookupObjective::new(&ds, 4, Target::Cost, MeasureMode::SingleDraw, 13);
        let mut led = EvalLedger::new(&src2, 4);
        let mut shards = led.shard(1, 4);
        let shard_vals: Vec<f64> = (0..4).map(|_| shards[0].eval(&cfg).unwrap()).collect();
        assert_eq!(direct_vals, shard_vals);
    }

    /// Pull counters survive merge: a config measured through a shard
    /// continues its pull sequence when the parent (or a later shard)
    /// measures it next.
    #[test]
    fn pull_counters_continue_across_merges() {
        let ds = ds();
        let cfg = provider_cfg(2);
        let src = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, 21);
        // Reference: 4 sequential pulls on one ledger.
        let mut led = EvalLedger::new(&src, 4);
        let want: Vec<f64> = (0..4).map(|_| led.eval(&cfg).unwrap()).collect();

        // Same pulls split 2 + 2 across a shard round-trip.
        let src2 = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, 21);
        let mut led2 = EvalLedger::new(&src2, 4);
        let mut shards = led2.shard(1, 2);
        let mut got = vec![shards[0].eval(&cfg).unwrap(), shards[0].eval(&cfg).unwrap()];
        led2.merge_all(&mut shards);
        got.push(led2.eval(&cfg).unwrap());
        got.push(led2.eval(&cfg).unwrap());
        assert_eq!(want, got);
    }

    #[test]
    fn shard_memo_inherits_and_folds_back() {
        let ds = ds();
        let cfg = provider_cfg(1);
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 1);
        let mut led = EvalLedger::new(&src, 6).with_memo();
        let v0 = led.eval(&cfg).unwrap();
        let mut shards = led.shard(1, 3);
        // Shard sees the parent's memo: repeat eval is a hit, not a charge.
        let v1 = shards[0].eval(&cfg).unwrap();
        assert_eq!(v0, v1);
        led.merge_all(&mut shards);
        assert_eq!(led.evals(), 2);
        assert_eq!(led.total_expense(), v0, "shard memo hit was not re-charged");
        // And post-merge parent evals still hit the memo.
        let v2 = led.eval(&cfg).unwrap();
        assert_eq!(v0, v2);
        assert_eq!(led.total_expense(), v0);
    }

    /// Overlapping arms under a deterministic memoized source: shards
    /// evaluating the SAME configurations concurrently share
    /// measurements through the shared memo, and the merged ledger
    /// (history, trace, expense) is identical to sequential execution —
    /// charging is reconciled in canonical merge order, not by which
    /// thread measured first.
    #[test]
    fn overlapping_memoized_shards_match_sequential_bit_for_bit() {
        let ds = ds();
        let run = |parallel: bool| {
            let cfgs = [provider_cfg(0), provider_cfg(1)];
            let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 5);
            let mut led = EvalLedger::new(&src, 8).with_memo();
            let shards = led.shard(2, 4);
            let work = |mut s: LedgerShard<'_>| {
                for c in &cfgs {
                    s.eval(c).unwrap();
                    s.eval(c).unwrap();
                }
                s
            };
            let mut shards: Vec<LedgerShard<'_>> = if parallel {
                crate::util::threadpool::parallel_map_owned(shards, 2, work)
            } else {
                shards.into_iter().map(work).collect()
            };
            led.merge_all(&mut shards);
            let vals: Vec<u64> = led.history().iter().map(|(_, v)| v.to_bits()).collect();
            (vals, led.trace().to_vec(), led.total_expense().to_bits(), led.evals())
        };
        let seq = run(false);
        // Both shards hit every config; expense charges each distinct
        // config exactly once, whoever measured it.
        let distinct: f64 = {
            let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 5);
            src.measure(&provider_cfg(0), 0) + src.measure(&provider_cfg(1), 0)
        };
        assert_eq!(seq.2, distinct.to_bits());
        assert_eq!(seq.3, 8);
        for _ in 0..4 {
            assert_eq!(seq, run(true), "parallel overlapping shards diverged");
        }
    }

    // -- cancellation -------------------------------------------------------

    use crate::util::cancel::{CancelReason, CancelToken};

    /// A token fired before the run starts still lets exactly one pull
    /// through (the guaranteed-first-pull rule), then stops everything.
    #[test]
    fn prefired_token_allows_exactly_one_pull() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 11);
        let token = CancelToken::new();
        token.cancel(CancelReason::Disconnect);
        let mut led = EvalLedger::new(&src, 20).with_cancel(token);
        assert!(!led.exhausted(), "no grant yet: cancellation not honored before first pull");
        assert!(led.eval(&some_cfg()).is_some());
        for _ in 0..5 {
            assert!(led.eval(&some_cfg()).is_none());
        }
        assert!(led.exhausted());
        assert_eq!(led.evals(), 1);
        assert_eq!(led.pulls_saved(), 19);
        assert_eq!(led.cancelled(), Some("disconnect"));
    }

    /// Firing mid-run stops at the next pull and leaves the completed
    /// prefix bit-identical to an uncancelled run.
    #[test]
    fn mid_run_cancel_keeps_prefix_bit_identical() {
        let ds = ds();
        let cfg = provider_cfg(0);
        let run = |cancel_after: Option<usize>| {
            let src = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, 17);
            let token = CancelToken::new();
            let mut led = EvalLedger::new(&src, 8).with_cancel(token.clone());
            let mut vals = Vec::new();
            for i in 0..8 {
                if cancel_after == Some(i) {
                    token.cancel(CancelReason::Deadline);
                }
                match led.eval(&cfg) {
                    Some(v) => vals.push(v.to_bits()),
                    None => break,
                }
            }
            vals
        };
        let full = run(None);
        assert_eq!(full.len(), 8);
        let cut = run(Some(3));
        assert_eq!(cut.len(), 3);
        assert_eq!(cut, full[..3], "completed prefix diverged from uncancelled run");
    }

    /// An uncancelled ledger reports no cancellation and saves nothing;
    /// a token firing only after exhaustion also does not mark the run.
    #[test]
    fn cancelled_is_none_for_complete_runs() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let token = CancelToken::new();
        let mut led = EvalLedger::new(&src, 2).with_cancel(token.clone());
        led.eval(&some_cfg());
        led.eval(&some_cfg());
        assert_eq!(led.remaining(), 0);
        token.cancel(CancelReason::Deadline);
        assert_eq!(led.cancelled(), None, "budget ran out first: the run is complete");
        assert_eq!(led.pulls_saved(), 0);
    }

    /// Shards inherit the parent's token: one fire stops every arm at
    /// its next pull.
    #[test]
    fn shards_inherit_cancellation() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 1, Target::Cost, MeasureMode::SingleDraw, 7);
        let token = CancelToken::new();
        let mut led = EvalLedger::new(&src, 20).with_cancel(token.clone());
        let mut shards = led.shard(2, 10);
        assert!(shards[0].eval(&provider_cfg(0)).is_some());
        token.cancel(CancelReason::Disconnect);
        assert!(shards[0].eval(&provider_cfg(0)).is_none());
        assert!(shards[1].eval(&provider_cfg(1)).is_none());
        assert!(shards.iter().all(|s| s.exhausted()));
        led.merge_all(&mut shards);
        assert_eq!(led.evals(), 1);
        assert_eq!(led.cancelled(), Some("disconnect"));
        assert_eq!(led.pulls_saved(), 19);
    }

    /// Concurrency stress: many shards with effectively unlimited local
    /// allowance hammering one small pool never over-admit, and every
    /// admitted evaluation is accounted for after merge.
    #[test]
    fn budget_pool_never_over_admits_under_concurrent_reservations() {
        let ds = ds();
        let src = LookupObjective::new(&ds, 6, Target::Cost, MeasureMode::SingleDraw, 3);
        for (budget, n_shards) in [(100usize, 8usize), (7, 8), (1, 4), (0, 4)] {
            let mut led = EvalLedger::new(&src, budget);
            let shards = led.shard(n_shards, usize::MAX);
            let mut shards = crate::util::threadpool::parallel_map_owned(
                shards,
                n_shards,
                |mut shard| {
                    let cfg = provider_cfg(0);
                    // Try far more than the pool holds.
                    for _ in 0..(2 * budget + 8) {
                        let _ = shard.eval(&cfg);
                    }
                    shard
                },
            );
            let admitted: usize = shards.iter().map(|s| s.pending()).sum();
            assert_eq!(admitted, budget, "{n_shards} shards over/under-admitted");
            assert_eq!(led.remaining(), 0);
            led.merge_all(&mut shards);
            assert_eq!(led.evals(), budget);
            // Still nothing more to give.
            assert!(led.eval(&provider_cfg(0)).is_none());
        }
    }
}
