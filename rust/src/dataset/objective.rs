//! The black-box objective f_k(n, x) (paper §III-A) with budget
//! accounting, backed by the offline store.
//!
//! Every optimizer sees only this interface: submit a configuration, get a
//! scalar back. The objective records the full evaluation history so the
//! coordinator can compute search expense (C_opt in the savings analysis)
//! and enforce budgets.

use super::{OfflineDataset, Target};
use crate::domain::Config;
use crate::util::rng::Rng;

/// How one evaluation aggregates the stored repetitions (paper §III-A:
/// "a single measurement or any chosen metric based on multiple
/// measurements, such as the mean or the 90th percentile").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeasureMode {
    /// One stored repetition chosen at random per evaluation (the paper's
    /// default online behaviour).
    SingleDraw,
    Mean,
    P90,
}

/// Black-box objective interface used by all optimizers.
pub trait Objective {
    /// Evaluate a configuration (consumes one unit of search budget).
    fn eval(&mut self, cfg: &Config) -> f64;
    /// Number of evaluations performed so far.
    fn evals(&self) -> usize;
}

/// Offline-store-backed objective for one (workload, target) task.
pub struct LookupObjective<'a> {
    ds: &'a OfflineDataset,
    pub workload: usize,
    pub target: Target,
    pub mode: MeasureMode,
    rng: Rng,
    history: Vec<(Config, f64)>,
}

impl<'a> LookupObjective<'a> {
    pub fn new(
        ds: &'a OfflineDataset,
        workload: usize,
        target: Target,
        mode: MeasureMode,
        seed: u64,
    ) -> Self {
        assert!(workload < ds.workload_count());
        LookupObjective { ds, workload, target, mode, rng: Rng::new(seed), history: Vec::new() }
    }

    pub fn history(&self) -> &[(Config, f64)] {
        &self.history
    }

    pub fn domain(&self) -> &crate::domain::Domain {
        &self.ds.domain
    }

    /// Total expense (sum of the target metric over every evaluation made
    /// so far) — the C_opt term of the §IV-E savings analysis. For the
    /// time target this is seconds spent; for cost, dollars spent.
    pub fn total_expense(&self) -> f64 {
        self.history.iter().map(|(_, v)| v).sum()
    }

    /// Best (config, value) seen so far.
    pub fn best(&self) -> Option<(&Config, f64)> {
        self.history
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(c, v)| (c, *v))
    }

    /// Peek at the value without consuming budget (used by tests and the
    /// savings analysis to price the *returned* configuration by its mean).
    pub fn ground_truth(&self, cfg: &Config) -> f64 {
        let cid = self.ds.domain.config_id(cfg);
        self.ds.mean_value(self.workload, cid, self.target)
    }
}

impl Objective for LookupObjective<'_> {
    fn eval(&mut self, cfg: &Config) -> f64 {
        let cid = self.ds.domain.config_id(cfg);
        let ms = self.ds.measurements(self.workload, cid);
        let v = match self.mode {
            MeasureMode::SingleDraw => {
                self.target.pick(ms[self.rng.usize_below(ms.len())])
            }
            MeasureMode::Mean => {
                ms.iter().map(|&m| self.target.pick(m)).sum::<f64>() / ms.len() as f64
            }
            MeasureMode::P90 => {
                let vals: Vec<f64> = ms.iter().map(|&m| self.target.pick(m)).collect();
                crate::util::stats::percentile(&vals, 90.0)
            }
        };
        self.history.push((cfg.clone(), v));
        v
    }

    fn evals(&self) -> usize {
        self.history.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::Config;

    fn ds() -> OfflineDataset {
        OfflineDataset::generate(1, 5)
    }

    fn some_cfg() -> Config {
        Config { provider: 0, choices: vec![1, 0], nodes: 3 }
    }

    #[test]
    fn eval_consumes_budget_and_records_history() {
        let ds = ds();
        let mut obj = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        assert_eq!(obj.evals(), 0);
        let v = obj.eval(&some_cfg());
        assert!(v > 0.0);
        assert_eq!(obj.evals(), 1);
        assert_eq!(obj.history()[0].1, v);
        assert_eq!(obj.total_expense(), v);
    }

    #[test]
    fn mean_mode_is_deterministic() {
        let ds = ds();
        let mut a = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 1);
        let mut b = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 999);
        assert_eq!(a.eval(&some_cfg()), b.eval(&some_cfg()));
    }

    #[test]
    fn single_draw_depends_on_seed_but_stays_in_range() {
        let ds = ds();
        let cfg = some_cfg();
        let cid = ds.domain.config_id(&cfg);
        let vals: Vec<f64> =
            ds.measurements(2, cid).iter().map(|&m| Target::Time.pick(m)).collect();
        let (lo, hi) = (crate::util::stats::min(&vals), crate::util::stats::max(&vals));
        for seed in 0..20 {
            let mut o = LookupObjective::new(&ds, 2, Target::Time, MeasureMode::SingleDraw, seed);
            let v = o.eval(&cfg);
            assert!(v >= lo && v <= hi);
        }
    }

    #[test]
    fn p90_at_least_median() {
        let ds = ds();
        let mut p90 = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::P90, 1);
        let mut mean = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::Mean, 1);
        let cfg = some_cfg();
        assert!(p90.eval(&cfg) >= mean.eval(&cfg) * 0.9);
    }

    #[test]
    fn best_tracks_minimum() {
        let ds = ds();
        let mut o = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 3);
        let grid = ds.domain.full_grid();
        for c in grid.iter().take(10) {
            o.eval(c);
        }
        let (bc, bv) = o.best().unwrap();
        assert!(o.history().iter().all(|(_, v)| *v >= bv));
        assert_eq!(o.ground_truth(bc), bv); // Mean mode = ground truth
    }
}
