//! The black-box objective f_k(n, x) (paper §III-A) and the evaluation
//! ledger every optimizer runs against.
//!
//! Two layers:
//!
//! * [`EvalSource`] / [`LookupObjective`] — the raw measurement source:
//!   map a configuration to one observed scalar, backed by the offline
//!   store. Stateless apart from its measurement RNG.
//! * [`EvalLedger`] — the single evaluation substrate shared by the whole
//!   optimizer suite. It owns history recording, best-so-far tracing,
//!   search-expense accounting (the C_opt term of the §IV-E savings
//!   analysis), **hard budget enforcement** (an optimizer physically
//!   cannot overspend: `eval` refuses once the budget is gone), and
//!   opt-in memoization for deterministic measure modes.
//!
//! Optimizers never see the source directly — they only hold a ledger, so
//! per-optimizer history/budget bookkeeping cannot drift and the
//! coordinator reads expense/evals/trace from one place.

use std::collections::HashMap;

use super::{OfflineDataset, Target};
use crate::domain::Config;
use crate::util::rng::Rng;

/// How one evaluation aggregates the stored repetitions (paper §III-A:
/// "a single measurement or any chosen metric based on multiple
/// measurements, such as the mean or the 90th percentile").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeasureMode {
    /// One stored repetition chosen at random per evaluation (the paper's
    /// default online behaviour).
    SingleDraw,
    Mean,
    P90,
}

impl MeasureMode {
    /// Whether repeated evaluations of one configuration always return
    /// the same value. Only deterministic modes may be memoized.
    pub fn deterministic(self) -> bool {
        !matches!(self, MeasureMode::SingleDraw)
    }
}

/// A raw measurement source: one configuration in, one observed scalar
/// out. Implementations do **no** bookkeeping — that is the ledger's job.
pub trait EvalSource {
    fn measure(&mut self, cfg: &Config) -> f64;

    /// True when repeated measurements of the same configuration are
    /// identical; gates [`EvalLedger::with_memo`].
    fn deterministic(&self) -> bool {
        false
    }
}

/// Offline-store-backed measurement source for one (workload, target)
/// task.
pub struct LookupObjective<'a> {
    ds: &'a OfflineDataset,
    pub workload: usize,
    pub target: Target,
    pub mode: MeasureMode,
    rng: Rng,
}

impl<'a> LookupObjective<'a> {
    pub fn new(
        ds: &'a OfflineDataset,
        workload: usize,
        target: Target,
        mode: MeasureMode,
        seed: u64,
    ) -> Self {
        assert!(workload < ds.workload_count());
        LookupObjective { ds, workload, target, mode, rng: Rng::new(seed) }
    }

    pub fn domain(&self) -> &crate::domain::Domain {
        &self.ds.domain
    }

    /// Peek at the mean value without going through a ledger (used by
    /// tests and the savings analysis to price the *returned*
    /// configuration by its ground truth).
    pub fn ground_truth(&self, cfg: &Config) -> f64 {
        let cid = self.ds.domain.config_id(cfg);
        self.ds.mean_value(self.workload, cid, self.target)
    }
}

impl EvalSource for LookupObjective<'_> {
    fn measure(&mut self, cfg: &Config) -> f64 {
        let cid = self.ds.domain.config_id(cfg);
        let ms = self.ds.measurements(self.workload, cid);
        match self.mode {
            MeasureMode::SingleDraw => {
                self.target.pick(ms[self.rng.usize_below(ms.len())])
            }
            MeasureMode::Mean => {
                ms.iter().map(|&m| self.target.pick(m)).sum::<f64>() / ms.len() as f64
            }
            MeasureMode::P90 => {
                let vals: Vec<f64> = ms.iter().map(|&m| self.target.pick(m)).collect();
                crate::util::stats::percentile(&vals, 90.0)
            }
        }
    }

    fn deterministic(&self) -> bool {
        self.mode.deterministic()
    }
}

/// Budget-enforcing evaluation ledger: the only handle optimizers get.
pub struct EvalLedger<'a> {
    source: &'a mut dyn EvalSource,
    budget: usize,
    history: Vec<(Config, f64)>,
    /// Best-so-far observed value after each evaluation.
    trace: Vec<f64>,
    /// Index into `history` of the best observation so far.
    best_idx: Option<usize>,
    /// Sum of the target metric over every *charged* evaluation (memo
    /// hits are free: the measurement was already paid for).
    expense: f64,
    memo: Option<HashMap<Config, f64>>,
}

impl<'a> EvalLedger<'a> {
    /// A ledger with a hard evaluation cap. There is deliberately no
    /// "unlimited" constructor: every optimizer loop runs to budget
    /// exhaustion, so an uncapped ledger would never terminate — callers
    /// with a fixed known cost (the predictive baselines) size the
    /// budget to exactly that cost instead.
    pub fn new(source: &'a mut dyn EvalSource, budget: usize) -> Self {
        EvalLedger {
            source,
            budget,
            history: Vec::new(),
            trace: Vec::new(),
            best_idx: None,
            expense: 0.0,
            memo: None,
        }
    }

    /// Enable memoization: repeated evaluations of one configuration
    /// replay the recorded value (still consuming budget and appearing in
    /// the history) without being charged as expense again.
    ///
    /// Panics for non-deterministic sources — `MeasureMode::SingleDraw`
    /// legitimately re-draws on repeat evaluations, so caching would
    /// change the objective's semantics.
    pub fn with_memo(mut self) -> Self {
        assert!(
            self.source.deterministic(),
            "memoization requires a deterministic measure mode (Mean/P90)"
        );
        self.memo = Some(HashMap::new());
        self
    }

    /// The evaluation cap this ledger enforces.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Evaluations still available.
    pub fn remaining(&self) -> usize {
        self.budget - self.history.len()
    }

    pub fn exhausted(&self) -> bool {
        self.history.len() >= self.budget
    }

    /// Evaluate a configuration, consuming one unit of budget. Returns
    /// `None` — performing no measurement — once the budget is exhausted;
    /// the ledger is the budget's enforcement point, not a convention.
    pub fn eval(&mut self, cfg: &Config) -> Option<f64> {
        if self.exhausted() {
            return None;
        }
        let (v, charged) = match &mut self.memo {
            Some(memo) => match memo.get(cfg) {
                Some(&v) => (v, false),
                None => {
                    let v = self.source.measure(cfg);
                    memo.insert(cfg.clone(), v);
                    (v, true)
                }
            },
            None => (self.source.measure(cfg), true),
        };
        if charged {
            self.expense += v;
        }
        let best = self.trace.last().copied().unwrap_or(f64::INFINITY);
        if v < best {
            self.best_idx = Some(self.history.len());
        }
        self.trace.push(best.min(v));
        self.history.push((cfg.clone(), v));
        Some(v)
    }

    /// Evaluate, panicking on an exhausted budget. For callers with a
    /// fixed, known evaluation count that sized the ledger themselves
    /// (the predictive baselines); search loops should use
    /// [`eval`](Self::eval) and stop on `None`.
    pub fn must_eval(&mut self, cfg: &Config) -> f64 {
        self.eval(cfg).expect("evaluation budget exhausted")
    }

    /// Number of evaluations performed so far.
    pub fn evals(&self) -> usize {
        self.history.len()
    }

    /// Full evaluation log in order.
    pub fn history(&self) -> &[(Config, f64)] {
        &self.history
    }

    /// Best-so-far observed value after each evaluation.
    pub fn trace(&self) -> &[f64] {
        &self.trace
    }

    /// Total search expense (sum of the target metric over every charged
    /// evaluation) — the C_opt term of the §IV-E savings analysis. For
    /// the time target this is seconds spent; for cost, dollars spent.
    pub fn total_expense(&self) -> f64 {
        self.expense
    }

    /// Best (config, observed value) seen so far.
    pub fn best(&self) -> Option<(&Config, f64)> {
        self.best_idx.map(|i| (&self.history[i].0, self.history[i].1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::Config;

    fn ds() -> OfflineDataset {
        OfflineDataset::generate(1, 5)
    }

    fn some_cfg() -> Config {
        Config { provider: 0, choices: vec![1, 0], nodes: 3 }
    }

    #[test]
    fn eval_consumes_budget_and_records_history() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let mut led = EvalLedger::new(&mut src, 4);
        assert_eq!(led.evals(), 0);
        assert_eq!(led.remaining(), 4);
        let v = led.eval(&some_cfg()).unwrap();
        assert!(v > 0.0);
        assert_eq!(led.evals(), 1);
        assert_eq!(led.history()[0].1, v);
        assert_eq!(led.trace(), &[v]);
        assert_eq!(led.total_expense(), v);
    }

    #[test]
    fn budget_is_physically_enforced() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 9);
        let mut led = EvalLedger::new(&mut src, 3);
        for _ in 0..3 {
            assert!(led.eval(&some_cfg()).is_some());
        }
        assert!(led.exhausted());
        // Trying harder does not help: no measurement happens.
        for _ in 0..10 {
            assert!(led.eval(&some_cfg()).is_none());
        }
        assert_eq!(led.evals(), 3);
        assert_eq!(led.remaining(), 0);
    }

    #[test]
    fn mean_mode_is_deterministic() {
        let ds = ds();
        let mut a = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 1);
        let mut b = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::Mean, 999);
        assert_eq!(a.measure(&some_cfg()), b.measure(&some_cfg()));
        assert!(a.deterministic());
    }

    #[test]
    fn single_draw_depends_on_seed_but_stays_in_range() {
        let ds = ds();
        let cfg = some_cfg();
        let cid = ds.domain.config_id(&cfg);
        let vals: Vec<f64> =
            ds.measurements(2, cid).iter().map(|&m| Target::Time.pick(m)).collect();
        let (lo, hi) = (crate::util::stats::min(&vals), crate::util::stats::max(&vals));
        for seed in 0..20 {
            let mut o = LookupObjective::new(&ds, 2, Target::Time, MeasureMode::SingleDraw, seed);
            assert!(!o.deterministic());
            let v = o.measure(&cfg);
            assert!(v >= lo && v <= hi);
        }
    }

    #[test]
    fn p90_at_least_median() {
        let ds = ds();
        let mut p90 = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::P90, 1);
        let mut mean = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::Mean, 1);
        let cfg = some_cfg();
        assert!(p90.measure(&cfg) >= mean.measure(&cfg) * 0.9);
    }

    #[test]
    fn best_and_trace_track_minimum() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 3);
        let grid = ds.domain.full_grid();
        let mut led = EvalLedger::new(&mut src, 10);
        for c in grid.iter().take(10) {
            led.eval(c);
        }
        let (bc, bv) = led.best().unwrap();
        assert!(led.history().iter().all(|(_, v)| *v >= bv));
        assert_eq!(*led.trace().last().unwrap(), bv);
        assert!(led.trace().windows(2).all(|w| w[1] <= w[0]));
        let bc = bc.clone();
        drop(led);
        assert_eq!(src.ground_truth(&bc), bv); // Mean mode = ground truth
    }

    #[test]
    fn memo_hits_replay_value_and_consume_budget_but_not_expense() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 1);
        let mut led = EvalLedger::new(&mut src, 5).with_memo();
        let cfg = some_cfg();
        let v1 = led.eval(&cfg).unwrap();
        let v2 = led.eval(&cfg).unwrap();
        assert_eq!(v1, v2);
        assert_eq!(led.evals(), 2, "memo hits still consume budget");
        assert_eq!(led.total_expense(), v1, "memo hits are not re-charged");
        assert_eq!(led.history().len(), 2, "memo hits still appear in history");
    }

    #[test]
    #[should_panic(expected = "memoization requires a deterministic measure mode")]
    fn memo_refused_for_single_draw() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 1);
        let _ = EvalLedger::new(&mut src, 5).with_memo();
    }

    #[test]
    #[should_panic(expected = "evaluation budget exhausted")]
    fn must_eval_panics_rather_than_overspending() {
        let ds = ds();
        let mut src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 1);
        let mut led = EvalLedger::new(&mut src, 1);
        led.must_eval(&some_cfg());
        led.must_eval(&some_cfg());
    }
}
