//! Bayesian optimization core + the two multi-cloud adaptations of §III-B.
//!
//! [`BoState`] is an *incrementally steppable* BO loop over an explicit
//! candidate grid (a provider's grid, or the flattened multi-cloud grid):
//! CloudBandit and Rising Bandits pull arms by stepping these states, and
//! the standalone `x1` / `x3` optimizers drive them to budget exhaustion.
//!
//! GP-backed presets hold a [`GpSession`](crate::surrogate::GpSession)
//! from the context's backend, so each new observation is a rank-1
//! Cholesky append (O(n²)) on the native backend instead of a from-scratch
//! refit (O(n³) × 4 lengthscales) per proposal.
//!
//! Presets:
//! * **CherryPick** [1]: GP surrogate (Matern-5/2) + EI.
//! * **Bilal et al.** [3]: GP + LCB when optimizing cost, RF + PI when
//!   optimizing time (their reported best flavours).
//!
//! Both presets may re-evaluate configurations (scikit-optimize behaviour;
//! the paper calls out the wasted evaluations this causes — SMAC-lite's
//! no-repeat rule is its advantage).

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::{EvalLedger, EvalSink, LedgerShard};
use crate::dataset::Target;
use crate::domain::{encode, Config};
use crate::linalg::Matrix;
use crate::surrogate::rf::{RandomForest, RfParams};
use crate::surrogate::{Acquisition, GpSession, Prediction, Surrogate};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map_owned;

/// Which surrogate a preset uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SurrogateKind {
    Gp,
    Rf,
}

#[derive(Clone, Copy, Debug)]
pub struct BoPreset {
    pub surrogate: SurrogateKind,
    pub acquisition: Acquisition,
    pub n_init: usize,
    /// Whether the proposal may revisit already-evaluated candidates.
    pub allow_repeats: bool,
}

impl BoPreset {
    pub fn cherrypick() -> BoPreset {
        BoPreset {
            surrogate: SurrogateKind::Gp,
            acquisition: Acquisition::Ei,
            n_init: 3,
            allow_repeats: true,
        }
    }

    /// Bilal et al.: target-dependent best flavour.
    pub fn bilal(target: Target) -> BoPreset {
        match target {
            Target::Cost => BoPreset {
                surrogate: SurrogateKind::Gp,
                acquisition: Acquisition::Lcb { kappa: 1.96 },
                n_init: 3,
                allow_repeats: true,
            },
            Target::Time => BoPreset {
                surrogate: SurrogateKind::Rf,
                acquisition: Acquisition::Pi,
                n_init: 3,
                allow_repeats: true,
            },
        }
    }
}

/// Steppable BO over a fixed candidate set. The `'a` lifetime ties the
/// incremental GP session to the backend it came from. `Send`, so bandit
/// optimizers can pull arm states on worker threads.
pub struct BoState<'a> {
    pub cands: Vec<Config>,
    /// Encoded candidate grid, one configuration per row (row-major so
    /// the surrogate distance kernels stream contiguously).
    enc: Matrix,
    preset: BoPreset,
    /// Encoded observations, grown one row per step.
    obs_x: Matrix,
    pub(crate) obs_cfg_idx: Vec<usize>,
    pub(crate) ys: Vec<f64>,
    evaluated: Vec<bool>,
    rf_seed: u64,
    /// Incremental GP session (GP presets only), pinned to `enc` so
    /// per-iteration predictions hit the whitened candidate cache.
    gp: Option<Box<dyn GpSession + Send + 'a>>,
}

impl<'a> BoState<'a> {
    pub fn new(ctx: &SearchContext<'a>, cands: Vec<Config>, preset: BoPreset) -> BoState<'a> {
        assert!(!cands.is_empty());
        let enc = Matrix::from_rows(
            &cands.iter().map(|c| encode(ctx.domain, c)).collect::<Vec<Vec<f64>>>(),
        );
        let evaluated = vec![false; cands.len()];
        let gp = match preset.surrogate {
            SurrogateKind::Gp => {
                let mut session = ctx.backend.gp_session();
                session.pin_candidates(&enc);
                Some(session)
            }
            SurrogateKind::Rf => None,
        };
        let obs_x = Matrix::zeros(0, enc.cols);
        BoState {
            cands,
            enc,
            preset,
            obs_x,
            obs_cfg_idx: Vec::new(),
            ys: Vec::new(),
            evaluated,
            rf_seed: 0,
            gp,
        }
    }

    pub fn observations(&self) -> usize {
        self.ys.len()
    }

    /// Best (config, observed value) so far, if any.
    pub fn best(&self) -> Option<(Config, f64)> {
        let i = self
            .ys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
            .0;
        Some((self.cands[self.obs_cfg_idx[i]].clone(), self.ys[i]))
    }

    fn propose(&mut self, rng: &mut Rng) -> usize {
        // Init design: uniform random (distinct while possible).
        if self.obs_x.rows < self.preset.n_init {
            let unseen: Vec<usize> =
                (0..self.cands.len()).filter(|&i| !self.evaluated[i]).collect();
            return if unseen.is_empty() {
                rng.usize_below(self.cands.len())
            } else {
                *rng.choice(&unseen)
            };
        }

        let pred: Prediction = match self.preset.surrogate {
            SurrogateKind::Gp => self
                .gp
                .as_mut()
                .expect("GP preset carries a session")
                .predict_pinned(),
            SurrogateKind::Rf => {
                self.rf_seed += 1;
                let mut rf =
                    RandomForest::new(RfParams { seed: self.rf_seed, ..Default::default() });
                rf.fit_predict(&self.obs_x, &self.ys, &self.enc)
            }
        };
        let best_y = self.ys.iter().copied().fold(f64::INFINITY, f64::min);
        let excluded: Vec<bool> = if self.preset.allow_repeats {
            vec![false; self.cands.len()]
        } else {
            self.evaluated.clone()
        };
        self.preset
            .acquisition
            .argmax(&pred, best_y, &excluded)
            .unwrap_or_else(|| rng.usize_below(self.cands.len()))
    }

    /// One BO iteration: propose, evaluate, record. Returns the observed
    /// value, or None once the sink's budget is exhausted (nothing is
    /// proposed or recorded in that case). The sink is a whole ledger or
    /// one arm's [`LedgerShard`](crate::dataset::objective::LedgerShard).
    pub fn step(&mut self, sink: &mut dyn EvalSink, rng: &mut Rng) -> Option<f64> {
        if sink.exhausted() {
            return None;
        }
        let i = self.propose(rng);
        let v = sink.eval(&self.cands[i])?;
        self.obs_x.push_row(self.enc.row(i));
        if let Some(gp) = &mut self.gp {
            gp.observe(self.enc.row(i).to_vec(), v);
        }
        self.obs_cfg_idx.push(i);
        self.ys.push(v);
        self.evaluated[i] = true;
        Some(v)
    }
}

// ---------------------------------------------------------------------------
// §III-B1: flattened-domain adaptation ("x1")

pub struct FlattenedBo {
    label: &'static str,
    preset_for: fn(Target) -> BoPreset,
}

impl FlattenedBo {
    pub fn cherrypick() -> Self {
        FlattenedBo { label: "cherrypick-x1", preset_for: |_| BoPreset::cherrypick() }
    }

    pub fn bilal() -> Self {
        FlattenedBo { label: "bilal-x1", preset_for: BoPreset::bilal }
    }
}

impl Optimizer for FlattenedBo {
    fn name(&self) -> String {
        self.label.into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let mut state = BoState::new(ctx, ctx.domain.full_grid(), (self.preset_for)(ctx.target));
        while state.step(&mut *ledger, rng).is_some() {}
        SearchResult::from_ledger(ledger)
    }
}

// ---------------------------------------------------------------------------
// §III-B2: independent per-provider optimizers ("x3")

pub struct IndependentBo {
    label: &'static str,
    preset_for: fn(Target) -> BoPreset,
}

impl IndependentBo {
    pub fn cherrypick() -> Self {
        IndependentBo { label: "cherrypick-x3", preset_for: |_| BoPreset::cherrypick() }
    }

    pub fn bilal() -> Self {
        IndependentBo { label: "bilal-x3", preset_for: BoPreset::bilal }
    }
}

/// One provider's independent BO loop: state, ledger shard, forked RNG,
/// and its fixed budget share. Moved onto a worker thread whole.
struct ProviderTask<'c, 'l> {
    state: BoState<'c>,
    shard: LedgerShard<'l>,
    rng: Rng,
    share: usize,
}

impl Optimizer for IndependentBo {
    fn name(&self) -> String {
        self.label.into()
    }

    /// The ledger's budget is split equally across the K providers (B/K
    /// each, paper §III-B2); the leftover B mod K goes to the first
    /// providers. Because every share is fixed up front and the
    /// providers are fully independent (disjoint grids, forked RNGs,
    /// own surrogate state), each loop runs on its own [`LedgerShard`]
    /// — concurrently when `SearchContext::arm_workers > 1` — and the
    /// shards merge back in provider order, bit-identical to the
    /// sequential schedule at any worker count.
    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let k = ctx.domain.provider_count();
        let budget = ledger.remaining();
        let preset = (self.preset_for)(ctx.target);
        let tasks: Vec<ProviderTask> = ledger
            .shard(k, 0)
            .into_iter()
            .enumerate()
            .map(|(p, shard)| ProviderTask {
                state: BoState::new(ctx, ctx.domain.provider_grid(p), preset),
                shard,
                rng: rng.fork(p as u64),
                share: budget / k + usize::from(p < budget % k),
            })
            .collect();
        let mut tasks = parallel_map_owned(tasks, ctx.arm_workers, |mut t| {
            t.shard.grant(t.share);
            for _ in 0..t.share {
                if t.state.step(&mut t.shard, &mut t.rng).is_none() {
                    break;
                }
            }
            t
        });
        for t in tasks.iter_mut() {
            ledger.merge(&mut t.shard);
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    fn ctx<'a>(ds: &'a OfflineDataset, backend: &'a NativeBackend, t: Target) -> SearchContext<'a> {
        SearchContext::new(&ds.domain, t, backend)
    }

    #[test]
    fn bo_state_steps_and_tracks_best() {
        let ds = OfflineDataset::generate(1, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut ledger = EvalLedger::new(&src, 10);
        let mut st = BoState::new(&c, ds.domain.provider_grid(0), BoPreset::cherrypick());
        let mut rng = Rng::new(5);
        while st.step(&mut ledger, &mut rng).is_some() {}
        assert_eq!(st.observations(), 10);
        let (_, bv) = st.best().unwrap();
        assert!(st.ys.iter().all(|&y| y >= bv));
        assert_eq!(ledger.best().unwrap().1, bv);
    }

    #[test]
    fn cherrypick_x1_converges_close_to_optimum_with_large_budget() {
        let ds = OfflineDataset::generate(2, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let src = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::Mean, 2);
        let mut ledger = EvalLedger::new(&src, 44);
        let r = FlattenedBo::cherrypick().run(&c, &mut ledger, &mut Rng::new(3));
        let (_, true_min) = ds.true_min(5, Target::Cost);
        let mean = ds.random_strategy_value(5, Target::Cost);
        assert!(r.best_value < 0.5 * mean + 0.5 * true_min, "{} vs min {}", r.best_value, true_min);
    }

    #[test]
    fn independent_splits_budget_across_providers() {
        let ds = OfflineDataset::generate(3, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Time);
        let src = LookupObjective::new(&ds, 1, Target::Time, MeasureMode::SingleDraw, 4);
        let mut ledger = EvalLedger::new(&src, 10);
        IndependentBo::cherrypick().run(&c, &mut ledger, &mut Rng::new(6));
        // 10 = 4 + 3 + 3 across providers 0,1,2 in order.
        let per: Vec<usize> = (0..3)
            .map(|p| ledger.history().iter().filter(|(c, _)| c.provider == p).count())
            .collect();
        assert_eq!(per, vec![4, 3, 3]);
    }

    /// Parallel per-provider loops are bit-identical to sequential ones
    /// for both x3 flavours (GP and RF surrogates) across budgets,
    /// seeds, and targets — shares are fixed up front, RNGs forked per
    /// provider, and shards merge in provider order.
    #[test]
    fn independent_bo_parallel_matches_sequential_bit_for_bit() {
        let ds = OfflineDataset::generate(9, 3);
        let backend = NativeBackend;
        let flavours: [(fn() -> IndependentBo, &str); 2] = [
            (IndependentBo::cherrypick, "cherrypick-x3"),
            (IndependentBo::bilal, "bilal-x3"),
        ];
        for (make, label) in flavours {
            for target in [Target::Cost, Target::Time] {
                for budget in [3usize, 10, 22] {
                    for seed in [1u64, 6] {
                        let run = |workers: usize| {
                            let c = SearchContext::new(&ds.domain, target, &backend)
                                .with_arm_workers(workers);
                            let src = LookupObjective::new(
                                &ds,
                                8,
                                target,
                                MeasureMode::SingleDraw,
                                seed,
                            );
                            let mut ledger = EvalLedger::new(&src, budget);
                            let r = make().run(&c, &mut ledger, &mut Rng::new(seed));
                            (
                                r.best_config.clone(),
                                r.best_value.to_bits(),
                                ledger.history().to_vec(),
                                ledger.total_expense().to_bits(),
                            )
                        };
                        let seq = run(1);
                        for workers in [2usize, 4] {
                            assert_eq!(
                                seq,
                                run(workers),
                                "{label} target={target:?} B={budget} seed={seed} workers={workers}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bilal_uses_rf_for_time_and_gp_for_cost() {
        assert_eq!(BoPreset::bilal(Target::Time).surrogate, SurrogateKind::Rf);
        assert_eq!(BoPreset::bilal(Target::Cost).surrogate, SurrogateKind::Gp);
    }

    #[test]
    fn no_repeat_mode_visits_distinct_candidates() {
        let ds = OfflineDataset::generate(4, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 8);
        let mut ledger = EvalLedger::new(&src, 16);
        let preset = BoPreset { allow_repeats: false, ..BoPreset::cherrypick() };
        let mut st = BoState::new(&c, ds.domain.provider_grid(1), preset); // 16 configs
        let mut rng = Rng::new(9);
        while st.step(&mut ledger, &mut rng).is_some() {}
        let mut seen = st.obs_cfg_idx.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 16, "all 16 distinct configs visited");
    }

    /// GP presets carry an incremental session; the BO loop on top of it
    /// stays deterministic for a fixed seed.
    #[test]
    fn gp_session_runs_are_seed_deterministic() {
        let ds = OfflineDataset::generate(7, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let run = || {
            let src = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::Mean, 5);
            let mut ledger = EvalLedger::new(&src, 14);
            let mut st = BoState::new(&c, ds.domain.provider_grid(2), BoPreset::cherrypick());
            let mut rng = Rng::new(2);
            while st.step(&mut ledger, &mut rng).is_some() {}
            st.obs_cfg_idx.clone()
        };
        assert_eq!(run(), run());
    }
}
