//! Bayesian optimization core + the two multi-cloud adaptations of §III-B.
//!
//! [`BoState`] is an *incrementally steppable* BO loop over an explicit
//! candidate grid (a provider's grid, or the flattened multi-cloud grid):
//! CloudBandit and Rising Bandits pull arms by stepping these states, and
//! the standalone `x1` / `x3` optimizers drive them to budget exhaustion.
//!
//! Presets:
//! * **CherryPick** [1]: GP surrogate (Matern-5/2) + EI.
//! * **Bilal et al.** [3]: GP + LCB when optimizing cost, RF + PI when
//!   optimizing time (their reported best flavours).
//!
//! Both presets may re-evaluate configurations (scikit-optimize behaviour;
//! the paper calls out the wasted evaluations this causes — SMAC-lite's
//! no-repeat rule is its advantage).

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::Objective;
use crate::dataset::Target;
use crate::domain::{encode, Config};
use crate::surrogate::rf::{RandomForest, RfParams};
use crate::surrogate::{Acquisition, Prediction, Surrogate};
use crate::util::rng::Rng;

/// Which surrogate a preset uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SurrogateKind {
    Gp,
    Rf,
}

#[derive(Clone, Copy, Debug)]
pub struct BoPreset {
    pub surrogate: SurrogateKind,
    pub acquisition: Acquisition,
    pub n_init: usize,
    /// Whether the proposal may revisit already-evaluated candidates.
    pub allow_repeats: bool,
}

impl BoPreset {
    pub fn cherrypick() -> BoPreset {
        BoPreset {
            surrogate: SurrogateKind::Gp,
            acquisition: Acquisition::Ei,
            n_init: 3,
            allow_repeats: true,
        }
    }

    /// Bilal et al.: target-dependent best flavour.
    pub fn bilal(target: Target) -> BoPreset {
        match target {
            Target::Cost => BoPreset {
                surrogate: SurrogateKind::Gp,
                acquisition: Acquisition::Lcb { kappa: 1.96 },
                n_init: 3,
                allow_repeats: true,
            },
            Target::Time => BoPreset {
                surrogate: SurrogateKind::Rf,
                acquisition: Acquisition::Pi,
                n_init: 3,
                allow_repeats: true,
            },
        }
    }
}

/// Steppable BO over a fixed candidate set.
pub struct BoState {
    pub cands: Vec<Config>,
    enc: Vec<Vec<f64>>,
    preset: BoPreset,
    obs_x: Vec<Vec<f64>>,
    obs_cfg_idx: Vec<usize>,
    ys: Vec<f64>,
    evaluated: Vec<bool>,
    rf_seed: u64,
}

impl BoState {
    pub fn new(ctx: &SearchContext, cands: Vec<Config>, preset: BoPreset) -> BoState {
        assert!(!cands.is_empty());
        let enc = cands.iter().map(|c| encode(ctx.domain, c)).collect();
        let evaluated = vec![false; cands.len()];
        BoState { cands, enc, preset, obs_x: Vec::new(), obs_cfg_idx: Vec::new(), ys: Vec::new(), evaluated, rf_seed: 0 }
    }

    pub fn observations(&self) -> usize {
        self.ys.len()
    }

    /// The most recently evaluated (config, value), if any.
    pub fn last(&self) -> Option<(Config, f64)> {
        let i = *self.obs_cfg_idx.last()?;
        Some((self.cands[i].clone(), *self.ys.last()?))
    }

    /// Best (config, observed value) so far, if any.
    pub fn best(&self) -> Option<(Config, f64)> {
        let i = self
            .ys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
            .0;
        Some((self.cands[self.obs_cfg_idx[i]].clone(), self.ys[i]))
    }

    fn propose(&mut self, ctx: &SearchContext, rng: &mut Rng) -> usize {
        // Init design: uniform random (distinct while possible).
        if self.obs_x.len() < self.preset.n_init {
            let unseen: Vec<usize> =
                (0..self.cands.len()).filter(|&i| !self.evaluated[i]).collect();
            return if unseen.is_empty() {
                rng.usize_below(self.cands.len())
            } else {
                *rng.choice(&unseen)
            };
        }

        let pred: Prediction = match self.preset.surrogate {
            SurrogateKind::Gp => ctx.backend.gp_fit_predict(&self.obs_x, &self.ys, &self.enc),
            SurrogateKind::Rf => {
                self.rf_seed += 1;
                let mut rf = RandomForest::new(RfParams { seed: self.rf_seed, ..Default::default() });
                rf.fit_predict(&self.obs_x, &self.ys, &self.enc)
            }
        };
        let best_y = self.ys.iter().copied().fold(f64::INFINITY, f64::min);
        let excluded: Vec<bool> = if self.preset.allow_repeats {
            vec![false; self.cands.len()]
        } else {
            self.evaluated.clone()
        };
        self.preset
            .acquisition
            .argmax(&pred, best_y, &excluded)
            .unwrap_or_else(|| rng.usize_below(self.cands.len()))
    }

    /// One BO iteration: propose, evaluate, record. Returns the observed
    /// value.
    pub fn step(&mut self, ctx: &SearchContext, obj: &mut dyn Objective, rng: &mut Rng) -> f64 {
        let i = self.propose(ctx, rng);
        let v = obj.eval(&self.cands[i]);
        self.obs_x.push(self.enc[i].clone());
        self.obs_cfg_idx.push(i);
        self.ys.push(v);
        self.evaluated[i] = true;
        v
    }
}

// ---------------------------------------------------------------------------
// §III-B1: flattened-domain adaptation ("x1")

pub struct FlattenedBo {
    label: &'static str,
    preset_for: fn(Target) -> BoPreset,
}

impl FlattenedBo {
    pub fn cherrypick() -> Self {
        FlattenedBo { label: "cherrypick-x1", preset_for: |_| BoPreset::cherrypick() }
    }

    pub fn bilal() -> Self {
        FlattenedBo { label: "bilal-x1", preset_for: BoPreset::bilal }
    }
}

impl Optimizer for FlattenedBo {
    fn name(&self) -> String {
        self.label.into()
    }

    fn run(
        &self,
        ctx: &SearchContext,
        obj: &mut dyn Objective,
        budget: usize,
        rng: &mut Rng,
    ) -> SearchResult {
        let mut state = BoState::new(ctx, ctx.domain.full_grid(), (self.preset_for)(ctx.target));
        let mut history = Vec::with_capacity(budget);
        for _ in 0..budget {
            let v = state.step(ctx, obj, rng);
            let i = *state.obs_cfg_idx.last().unwrap();
            history.push((state.cands[i].clone(), v));
        }
        SearchResult::from_history(&history)
    }
}

// ---------------------------------------------------------------------------
// §III-B2: independent per-provider optimizers ("x3")

pub struct IndependentBo {
    label: &'static str,
    preset_for: fn(Target) -> BoPreset,
}

impl IndependentBo {
    pub fn cherrypick() -> Self {
        IndependentBo { label: "cherrypick-x3", preset_for: |_| BoPreset::cherrypick() }
    }

    pub fn bilal() -> Self {
        IndependentBo { label: "bilal-x3", preset_for: BoPreset::bilal }
    }
}

impl Optimizer for IndependentBo {
    fn name(&self) -> String {
        self.label.into()
    }

    /// Budget is split equally across the K providers (B/K each, paper
    /// §III-B2); the leftover B mod K goes to the first providers.
    fn run(
        &self,
        ctx: &SearchContext,
        obj: &mut dyn Objective,
        budget: usize,
        rng: &mut Rng,
    ) -> SearchResult {
        let k = ctx.domain.provider_count();
        let preset = (self.preset_for)(ctx.target);
        let mut history = Vec::with_capacity(budget);
        for p in 0..k {
            let share = budget / k + usize::from(p < budget % k);
            let mut state = BoState::new(ctx, ctx.domain.provider_grid(p), preset);
            for _ in 0..share {
                let v = state.step(ctx, obj, rng);
                let i = *state.obs_cfg_idx.last().unwrap();
                history.push((state.cands[i].clone(), v));
            }
        }
        SearchResult::from_history(&history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    fn ctx<'a>(ds: &'a OfflineDataset, backend: &'a NativeBackend, t: Target) -> SearchContext<'a> {
        SearchContext { domain: &ds.domain, target: t, backend }
    }

    #[test]
    fn bo_state_steps_and_tracks_best() {
        let ds = OfflineDataset::generate(1, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let mut obj = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut st = BoState::new(&c, ds.domain.provider_grid(0), BoPreset::cherrypick());
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            st.step(&c, &mut obj, &mut rng);
        }
        assert_eq!(st.observations(), 10);
        let (_, bv) = st.best().unwrap();
        assert!(st.ys.iter().all(|&y| y >= bv));
    }

    #[test]
    fn cherrypick_x1_converges_close_to_optimum_with_large_budget() {
        let ds = OfflineDataset::generate(2, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let mut obj = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::Mean, 2);
        let r = FlattenedBo::cherrypick().run(&c, &mut obj, 44, &mut Rng::new(3));
        let (_, true_min) = ds.true_min(5, Target::Cost);
        let mean = ds.random_strategy_value(5, Target::Cost);
        assert!(r.best_value < 0.5 * mean + 0.5 * true_min, "{} vs min {}", r.best_value, true_min);
    }

    #[test]
    fn independent_splits_budget_across_providers() {
        let ds = OfflineDataset::generate(3, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Time);
        let mut obj = LookupObjective::new(&ds, 1, Target::Time, MeasureMode::SingleDraw, 4);
        let mut rec = crate::optimizers::HistoryRecorder::new(&mut obj);
        IndependentBo::cherrypick().run(&c, &mut rec, 10, &mut Rng::new(6));
        // 10 = 4 + 3 + 3 across providers 0,1,2 in order.
        let per: Vec<usize> =
            (0..3).map(|p| rec.history.iter().filter(|(c, _)| c.provider == p).count()).collect();
        assert_eq!(per, vec![4, 3, 3]);
    }

    #[test]
    fn bilal_uses_rf_for_time_and_gp_for_cost() {
        assert_eq!(BoPreset::bilal(Target::Time).surrogate, SurrogateKind::Rf);
        assert_eq!(BoPreset::bilal(Target::Cost).surrogate, SurrogateKind::Gp);
    }

    #[test]
    fn no_repeat_mode_visits_distinct_candidates() {
        let ds = OfflineDataset::generate(4, 3);
        let backend = NativeBackend;
        let c = ctx(&ds, &backend, Target::Cost);
        let mut obj = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::SingleDraw, 8);
        let preset = BoPreset { allow_repeats: false, ..BoPreset::cherrypick() };
        let mut st = BoState::new(&c, ds.domain.provider_grid(1), preset); // 16 configs
        let mut rng = Rng::new(9);
        for _ in 0..16 {
            st.step(&c, &mut obj, &mut rng);
        }
        let mut seen = st.obs_cfg_idx.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 16, "all 16 distinct configs visited");
    }
}
