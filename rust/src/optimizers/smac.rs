//! SMAC-lite: sequential model-based algorithm configuration [17], [23].
//!
//! The AutoML optimizer that "natively leverages the hierarchy" (paper
//! §III-C) and wins most regret comparisons in Figure 3. Core mechanics
//! implemented here:
//!
//! * random-forest surrogate over the hierarchical encoding — conditional
//!   (foreign-provider) dimensions are zeroed exactly as SMAC imputes
//!   inactive conditionals, letting tree splits isolate provider subtrees;
//! * expected improvement acquisition over the full multi-cloud grid;
//! * interleaved random configurations (every `random_interleave`-th
//!   proposal) for guaranteed exploration, as in SMAC;
//! * **no repeated configurations** — evaluated points are excluded from
//!   the acquisition argmax (the advantage over HyperOpt the paper notes).

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::domain::encode;
use crate::linalg::Matrix;
use crate::surrogate::rf::{RandomForest, RfParams};
use crate::surrogate::{Acquisition, Surrogate};
use crate::util::rng::Rng;

pub struct SmacLite {
    pub n_init: usize,
    /// Every k-th proposal is uniform random (SMAC's interleaving).
    pub random_interleave: usize,
    pub n_trees: usize,
}

impl Default for SmacLite {
    fn default() -> Self {
        SmacLite { n_init: 3, random_interleave: 4, n_trees: 30 }
    }
}

impl Optimizer for SmacLite {
    fn name(&self) -> String {
        "smac".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let cands = ctx.domain.full_grid();
        let enc = Matrix::from_rows(
            &cands.iter().map(|c| encode(ctx.domain, c)).collect::<Vec<Vec<f64>>>(),
        );
        let mut evaluated = vec![false; cands.len()];
        let mut obs_x = Matrix::zeros(0, enc.cols);
        let mut ys: Vec<f64> = Vec::new();
        let mut rf_seed = 0u64;

        let mut it = 0;
        while !ledger.exhausted() {
            let unseen: Vec<usize> = (0..cands.len()).filter(|&i| !evaluated[i]).collect();
            let i = if unseen.is_empty() {
                // Grid exhausted (budget == domain size): random re-draw.
                rng.usize_below(cands.len())
            } else if obs_x.rows < self.n_init
                || (self.random_interleave > 0
                    && it % self.random_interleave == self.random_interleave - 1)
            {
                *rng.choice(&unseen)
            } else {
                rf_seed += 1;
                let mut rf = RandomForest::new(RfParams {
                    n_trees: self.n_trees,
                    seed: rf_seed,
                    ..Default::default()
                });
                let pred = rf.fit_predict(&obs_x, &ys, &enc);
                let best_y = ys.iter().copied().fold(f64::INFINITY, f64::min);
                Acquisition::Ei
                    .argmax(&pred, best_y, &evaluated)
                    .unwrap_or_else(|| *rng.choice(&unseen))
            };
            let Some(v) = ledger.eval(&cands[i]) else { break };
            evaluated[i] = true;
            obs_x.push_row(enc.row(i));
            ys.push(v);
            it += 1;
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn never_repeats_configurations_within_grid() {
        let ds = OfflineDataset::generate(8, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 6, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut ledger = EvalLedger::new(&src, 44);
        SmacLite::default().run(&ctx, &mut ledger, &mut Rng::new(2));
        let mut ids: Vec<usize> =
            ledger.history().iter().map(|(c, _)| ds.domain.config_id(c)).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 44, "SMAC-lite repeated a configuration");
    }

    #[test]
    fn finds_good_configs_with_moderate_budget() {
        let ds = OfflineDataset::generate(9, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let w = 20;
        let src = LookupObjective::new(&ds, w, Target::Time, MeasureMode::Mean, 5);
        let mut ledger = EvalLedger::new(&src, 33);
        let r = SmacLite::default().run(&ctx, &mut ledger, &mut Rng::new(6));
        let (_, tmin) = ds.true_min(w, Target::Time);
        let mean = ds.random_strategy_value(w, Target::Time);
        // Well into the best quartile of the gap between optimum and mean.
        assert!(r.best_value < tmin + 0.4 * (mean - tmin), "{} vs min {tmin}", r.best_value);
    }

    #[test]
    fn interleaving_disabled_still_works() {
        let ds = OfflineDataset::generate(10, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::SingleDraw, 7);
        let mut ledger = EvalLedger::new(&src, 20);
        let opt = SmacLite { random_interleave: 0, ..Default::default() };
        let r = opt.run(&ctx, &mut ledger, &mut Rng::new(8));
        assert_eq!(r.evals_used, 20);
    }
}
