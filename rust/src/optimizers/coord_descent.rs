//! Coordinate descent baseline (mentioned alongside RS in §II-B).
//!
//! Multi-cloud adaptation: pick a random provider and configuration, then
//! cycle over that provider's coordinates (each categorical parameter and
//! the node count), greedily evaluating every alternative value of one
//! coordinate while holding the others fixed. When a full sweep makes no
//! progress, restart at a new random provider/configuration. The ledger
//! caps the spend throughout.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::domain::Config;
use crate::util::rng::Rng;

pub struct CoordinateDescent;

fn random_config(ctx: &SearchContext, rng: &mut Rng) -> Config {
    let provider = rng.usize_below(ctx.domain.provider_count());
    let p = &ctx.domain.providers[provider];
    let choices = p.params.iter().map(|q| rng.usize_below(q.values.len())).collect();
    let nodes = *rng.choice(&ctx.domain.nodes);
    Config { provider, choices, nodes }
}

impl Optimizer for CoordinateDescent {
    fn name(&self) -> String {
        "cd".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        'outer: while !ledger.exhausted() {
            // Restart point.
            let mut current = random_config(ctx, rng);
            let Some(mut current_val) = ledger.eval(&current) else { break };
            loop {
                let mut improved = false;
                let p = &ctx.domain.providers[current.provider];
                // Coordinates: each categorical param, then nodes.
                for coord in 0..=p.params.len() {
                    let alternatives: Vec<Config> = if coord < p.params.len() {
                        (0..p.params[coord].values.len())
                            .filter(|&v| v != current.choices[coord])
                            .map(|v| {
                                let mut c = current.clone();
                                c.choices[coord] = v;
                                c
                            })
                            .collect()
                    } else {
                        ctx.domain
                            .nodes
                            .iter()
                            .filter(|&&n| n != current.nodes)
                            .map(|&n| {
                                let mut c = current.clone();
                                c.nodes = n;
                                c
                            })
                            .collect()
                    };
                    for alt in alternatives {
                        let Some(v) = ledger.eval(&alt) else { break 'outer };
                        if v < current_val {
                            current = alt;
                            current_val = v;
                            improved = true;
                        }
                    }
                }
                if !improved {
                    continue 'outer; // local optimum: restart elsewhere
                }
            }
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn respects_budget_and_improves_over_first_sample() {
        let ds = OfflineDataset::generate(6, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let src = LookupObjective::new(&ds, 9, Target::Time, MeasureMode::Mean, 3);
        let mut ledger = EvalLedger::new(&src, 30);
        let r = CoordinateDescent.run(&ctx, &mut ledger, &mut Rng::new(4));
        assert_eq!(r.evals_used, 30);
        assert!(r.best_value <= r.trace[0]);
    }

    #[test]
    fn local_search_stays_within_provider_until_restart() {
        // With a budget of 2 the second eval must share the provider of the
        // first (a coordinate move never switches provider).
        let ds = OfflineDataset::generate(6, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 3);
        let mut ledger = EvalLedger::new(&src, 2);
        CoordinateDescent.run(&ctx, &mut ledger, &mut Rng::new(8));
        let h = ledger.history();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].0.provider, h[1].0.provider);
    }
}
