//! The search-based optimizer suite (paper §III).
//!
//! Every method implements [`Optimizer`]: given a budget-enforcing
//! [`EvalLedger`], search for the best configuration. The suite covers
//!
//! * baselines: random search, exhaustive search, coordinate descent;
//! * single-cloud BO adapted to multi-cloud by flattening (`x1`) and by
//!   independent per-provider instances (`x3`) — CherryPick (GP+EI) and
//!   the Bilal et al. schemes (GP+LCB for cost, RF+PI for time);
//! * AutoML methods exploiting the hierarchy: SMAC-lite, HyperOpt-lite
//!   (TPE), Rising Bandits;
//! * RBFOpt-lite; and the paper's contribution, **CloudBandit**, with
//!   either CherryPick-BO or RBFOpt-lite as the component BBO.
//!
//! The ledger owns history, best-so-far tracing, expense accounting and
//! the hard budget cap, so optimizers carry no bookkeeping of their own:
//! [`SearchResult::from_ledger`] derives the outcome from the log.
//!
//! `by_name()` maps the CLI/figure names to constructors.

pub mod annealing;
pub mod bo;
pub mod cloudbandit;
pub mod coord_descent;
pub mod exhaustive;
pub mod hyperopt;
pub mod random;
pub mod rbfopt;
pub mod rising_bandits;
pub mod smac;

use crate::dataset::objective::EvalLedger;
use crate::dataset::Target;
use crate::domain::{Config, Domain};
use crate::surrogate::Backend;
use crate::util::rng::Rng;

/// Shared, read-only context for a search run.
pub struct SearchContext<'a> {
    pub domain: &'a Domain,
    pub target: Target,
    pub backend: &'a dyn Backend,
    /// Worker threads for parallel arm execution inside one trial (the
    /// bandit optimizers pull all active arms of a round concurrently).
    /// 1 = sequential. Results are bit-identical at any setting — the
    /// knob only trades wall-clock for cores — so it is excluded from
    /// seed derivation everywhere.
    pub arm_workers: usize,
    /// Providers whose capacity is revoked for this search (dynamic
    /// markets): provider-aware methods (CloudBandit) skip these arms
    /// entirely instead of wasting pulls on capacity that cannot host
    /// the workload. Empty (the default) = the static, all-available
    /// world; every pre-existing behaviour is unchanged.
    pub revoked: Vec<usize>,
}

impl<'a> SearchContext<'a> {
    pub fn new(domain: &'a Domain, target: Target, backend: &'a dyn Backend) -> SearchContext<'a> {
        SearchContext { domain, target, backend, arm_workers: 1, revoked: Vec::new() }
    }

    pub fn with_arm_workers(mut self, workers: usize) -> SearchContext<'a> {
        self.arm_workers = workers.max(1);
        self
    }

    /// Mark `providers` as revoked for this search.
    pub fn with_revoked(mut self, providers: Vec<usize>) -> SearchContext<'a> {
        self.revoked = providers;
        self
    }

    /// Providers available to place work on, in index order. Defensive:
    /// if every provider were marked revoked the full set is returned —
    /// a search must always have somewhere to look (the market layer
    /// never produces a full outage, see
    /// `simulator::market::revoked_providers`).
    pub fn available_providers(&self) -> Vec<usize> {
        let all = 0..self.domain.provider_count();
        let avail: Vec<usize> = all.clone().filter(|p| !self.revoked.contains(p)).collect();
        if avail.is_empty() { all.collect() } else { avail }
    }
}

/// Outcome of one search run.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub best_config: Config,
    /// Best observed objective value (noisy measurement units).
    pub best_value: f64,
    pub evals_used: usize,
    /// Best-so-far observed value after each evaluation.
    pub trace: Vec<f64>,
}

impl SearchResult {
    /// Derive the result from the ledger's log, returning the best
    /// *observed* configuration (the convention for every method except
    /// the bandits, which restrict to the surviving arm).
    pub fn from_ledger(ledger: &EvalLedger) -> SearchResult {
        let (cfg, best) = ledger.best().expect("search made no evaluations");
        SearchResult {
            best_config: cfg.clone(),
            best_value: best,
            evals_used: ledger.evals(),
            trace: ledger.trace().to_vec(),
        }
    }
}

/// A search-based multi-cloud configuration method.
pub trait Optimizer: Sync {
    fn name(&self) -> String;

    /// Ledger budget this method needs for a requested search budget.
    /// The default is the identity; exhaustive search asks for the full
    /// grid (its defining behaviour — the Fig. 4 strawman sweeps
    /// everything regardless of the nominal budget).
    fn provisioned_budget(&self, _ctx: &SearchContext, requested: usize) -> usize {
        requested
    }

    /// Run a search against the ledger. The ledger's budget is a hard
    /// cap: `EvalLedger::eval` returns `None` once it is spent, so an
    /// implementation *cannot* overspend — it only decides how to spend.
    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult;
}

/// All optimizer names understood by the CLI / experiment harness, in the
/// order figures present them.
pub const ALL_OPTIMIZERS: [&str; 15] = [
    "rs",
    "cd",
    "shc",
    "sa",
    "exhaustive",
    "cherrypick-x1",
    "cherrypick-x3",
    "bilal-x1",
    "bilal-x3",
    "smac",
    "hyperopt",
    "rb",
    "rbfopt",
    "cb-cherrypick",
    "cb-rbfopt",
];

/// Construct an optimizer by registry name.
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "rs" => Box::new(random::RandomSearch),
        "cd" => Box::new(coord_descent::CoordinateDescent),
        "shc" => Box::new(annealing::StochasticHillClimbing::default()),
        "sa" => Box::new(annealing::SimulatedAnnealing::default()),
        "exhaustive" => Box::new(exhaustive::ExhaustiveSearch),
        "cherrypick-x1" => Box::new(bo::FlattenedBo::cherrypick()),
        "cherrypick-x3" => Box::new(bo::IndependentBo::cherrypick()),
        "bilal-x1" => Box::new(bo::FlattenedBo::bilal()),
        "bilal-x3" => Box::new(bo::IndependentBo::bilal()),
        "smac" => Box::new(smac::SmacLite::default()),
        "hyperopt" => Box::new(hyperopt::HyperOptLite::default()),
        "rb" => Box::new(rising_bandits::RisingBandits::default()),
        "rbfopt" => Box::new(rbfopt::RbfOpt),
        "cb-cherrypick" => {
            Box::new(cloudbandit::CloudBandit::new(cloudbandit::Component::CherryPick, 2.0))
        }
        "cb-rbfopt" => Box::new(cloudbandit::CloudBandit::new(cloudbandit::Component::RbfOpt, 2.0)),
        _ => return None,
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::OfflineDataset;
    use crate::surrogate::NativeBackend;

    pub fn run_optimizer(
        name: &str,
        ds: &OfflineDataset,
        workload: usize,
        target: Target,
        budget: usize,
        seed: u64,
    ) -> (SearchResult, usize) {
        let opt = by_name(name).unwrap_or_else(|| panic!("unknown optimizer {name}"));
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, target, &backend);
        let src = LookupObjective::new(ds, workload, target, MeasureMode::SingleDraw, seed);
        let mut ledger = EvalLedger::new(&src, opt.provisioned_budget(&ctx, budget));
        let mut rng = Rng::new(seed ^ 0xABCD);
        let res = opt.run(&ctx, &mut ledger, &mut rng);
        let evals = ledger.evals();
        (res, evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::OfflineDataset;
    use crate::surrogate::NativeBackend;

    #[test]
    fn registry_covers_all_names() {
        for name in ALL_OPTIMIZERS {
            assert!(by_name(name).is_some(), "{name} missing from registry");
        }
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn from_ledger_tracks_best_so_far() {
        let ds = OfflineDataset::generate(77, 3);
        let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 1);
        let grid = ds.domain.full_grid();
        let mut ledger = EvalLedger::new(&src, 4);
        for c in grid.iter().take(4) {
            ledger.eval(c);
        }
        let r = SearchResult::from_ledger(&ledger);
        assert_eq!(r.evals_used, 4);
        assert_eq!(r.trace.len(), 4);
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0]));
        assert_eq!(*r.trace.last().unwrap(), r.best_value);
        let min = ledger
            .history()
            .iter()
            .map(|(_, v)| *v)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(r.best_value, min);
    }

    /// Every optimizer respects its budget and returns a config whose
    /// observed value matches the history minimum convention.
    #[test]
    fn all_optimizers_respect_budget() {
        let ds = OfflineDataset::generate(3, 3);
        for name in ALL_OPTIMIZERS {
            if name == "exhaustive" {
                continue; // provisions the full grid by definition
            }
            for budget in [11, 33] {
                let (res, evals) =
                    testutil::run_optimizer(name, &ds, 4, Target::Cost, budget, 17);
                assert!(evals <= budget, "{name} used {evals} > {budget}");
                assert!(evals >= budget.min(8), "{name} underused budget: {evals}");
                assert!(res.best_value.is_finite());
                assert_eq!(res.trace.len(), res.evals_used);
            }
        }
    }

    /// The ledger is the enforcement point: even handed a smaller budget
    /// than a method would schedule for itself (including exhaustive's
    /// full-grid sweep), no optimizer can spend past the cap — sequential
    /// or with parallel arm execution (shard reservations come out of one
    /// shared atomic pool).
    #[test]
    fn ledger_prevents_overspend_for_every_optimizer() {
        let ds = OfflineDataset::generate(5, 3);
        let backend = NativeBackend;
        for name in ALL_OPTIMIZERS {
            let opt = by_name(name).unwrap();
            for workers in [1usize, 4] {
                let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend)
                    .with_arm_workers(workers);
                for budget in [1usize, 5, 9] {
                    let src =
                        LookupObjective::new(&ds, 1, Target::Cost, MeasureMode::SingleDraw, 7);
                    let mut ledger = EvalLedger::new(&src, budget);
                    let res = opt.run(&ctx, &mut ledger, &mut Rng::new(11));
                    assert!(
                        ledger.evals() <= budget,
                        "{name} (workers={workers}) spent {} > hard cap {budget}",
                        ledger.evals()
                    );
                    assert!(res.best_value.is_finite(), "{name} at budget {budget}");
                }
            }
        }
    }

    /// Memoized deterministic evaluation composes with a real optimizer:
    /// repeat proposals replay values and are charged once.
    #[test]
    fn memoized_ledger_does_not_double_charge_repeats() {
        let ds = OfflineDataset::generate(6, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        // CherryPick allows repeat proposals, so a long run on a small
        // provider grid is guaranteed to revisit configurations.
        let opt = by_name("cherrypick-x1").unwrap();
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 3);
        let mut ledger = EvalLedger::new(&src, 40).with_memo();
        opt.run(&ctx, &mut ledger, &mut Rng::new(4));
        assert_eq!(ledger.evals(), 40);
        // Expense equals the sum over *distinct* configurations only.
        let mut seen = std::collections::HashMap::new();
        for (c, v) in ledger.history() {
            seen.entry(ds.domain.config_id(c)).or_insert(*v);
        }
        let distinct_sum: f64 = seen.values().sum();
        assert!(
            (ledger.total_expense() - distinct_sum).abs() < 1e-9,
            "expense {} vs distinct sum {} over {} distinct / {} evals",
            ledger.total_expense(),
            distinct_sum,
            seen.len(),
            ledger.evals()
        );
    }

    /// With a generous budget every method should land well below the
    /// domain's mean value (sanity: search actually searches).
    #[test]
    fn optimizers_beat_the_mean_at_large_budget() {
        let ds = OfflineDataset::generate(11, 3);
        let w = 7;
        let mean = ds.random_strategy_value(w, Target::Time);
        let (_, min) = ds.true_min(w, Target::Time);
        for name in ALL_OPTIMIZERS {
            let (res, _) = testutil::run_optimizer(name, &ds, w, Target::Time, 66, 5);
            assert!(
                res.best_value < mean,
                "{name}: best {} not below mean {mean} (min {min})",
                res.best_value
            );
        }
    }
}
