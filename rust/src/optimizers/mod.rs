//! The search-based optimizer suite (paper §III).
//!
//! Every method implements [`Optimizer`]: given a black-box objective and
//! a budget, return the best configuration found. The suite covers
//!
//! * baselines: random search, exhaustive search, coordinate descent;
//! * single-cloud BO adapted to multi-cloud by flattening (`x1`) and by
//!   independent per-provider instances (`x3`) — CherryPick (GP+EI) and
//!   the Bilal et al. schemes (GP+LCB for cost, RF+PI for time);
//! * AutoML methods exploiting the hierarchy: SMAC-lite, HyperOpt-lite
//!   (TPE), Rising Bandits;
//! * RBFOpt-lite; and the paper's contribution, **CloudBandit**, with
//!   either CherryPick-BO or RBFOpt-lite as the component BBO.
//!
//! `registry()` maps the CLI/figure names to constructors.

pub mod annealing;
pub mod bo;
pub mod cloudbandit;
pub mod coord_descent;
pub mod exhaustive;
pub mod hyperopt;
pub mod random;
pub mod rbfopt;
pub mod rising_bandits;
pub mod smac;

use crate::dataset::objective::Objective;
use crate::dataset::Target;
use crate::domain::{Config, Domain};
use crate::surrogate::Backend;
use crate::util::rng::Rng;

/// Shared, read-only context for a search run.
pub struct SearchContext<'a> {
    pub domain: &'a Domain,
    pub target: Target,
    pub backend: &'a dyn Backend,
}

/// Outcome of one search run.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub best_config: Config,
    /// Best observed objective value (noisy measurement units).
    pub best_value: f64,
    pub evals_used: usize,
    /// Best-so-far observed value after each evaluation.
    pub trace: Vec<f64>,
}

impl SearchResult {
    /// Build a result from the evaluation history, returning the best
    /// *observed* configuration (the convention for every method except
    /// CloudBandit, which restricts to the surviving arm).
    pub fn from_history(history: &[(Config, f64)]) -> SearchResult {
        assert!(!history.is_empty(), "search made no evaluations");
        let mut trace = Vec::with_capacity(history.len());
        let mut best = f64::INFINITY;
        let mut best_cfg = &history[0].0;
        for (c, v) in history {
            if *v < best {
                best = *v;
                best_cfg = c;
            }
            trace.push(best);
        }
        SearchResult {
            best_config: best_cfg.clone(),
            best_value: best,
            evals_used: history.len(),
            trace,
        }
    }
}

/// A search-based multi-cloud configuration method.
pub trait Optimizer: Sync {
    fn name(&self) -> String;

    /// Run a search with the given evaluation budget. Implementations must
    /// not exceed `budget` objective evaluations.
    fn run(
        &self,
        ctx: &SearchContext,
        obj: &mut dyn Objective,
        budget: usize,
        rng: &mut Rng,
    ) -> SearchResult;
}

#[cfg(test)]
/// History accessor used by optimizers that build their result from the
/// full log. Implemented via a shim: optimizers record their own history.
pub(crate) struct HistoryRecorder<'a> {
    inner: &'a mut dyn Objective,
    pub history: Vec<(Config, f64)>,
}

#[cfg(test)]
impl<'a> HistoryRecorder<'a> {
    pub fn new(inner: &'a mut dyn Objective) -> Self {
        HistoryRecorder { inner, history: Vec::new() }
    }
}

#[cfg(test)]
impl Objective for HistoryRecorder<'_> {
    fn eval(&mut self, cfg: &Config) -> f64 {
        let v = self.inner.eval(cfg);
        self.history.push((cfg.clone(), v));
        v
    }

    fn evals(&self) -> usize {
        self.inner.evals()
    }
}

/// All optimizer names understood by the CLI / experiment harness, in the
/// order figures present them.
pub const ALL_OPTIMIZERS: [&str; 15] = [
    "rs",
    "cd",
    "shc",
    "sa",
    "exhaustive",
    "cherrypick-x1",
    "cherrypick-x3",
    "bilal-x1",
    "bilal-x3",
    "smac",
    "hyperopt",
    "rb",
    "rbfopt",
    "cb-cherrypick",
    "cb-rbfopt",
];

/// Construct an optimizer by registry name.
pub fn by_name(name: &str) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "rs" => Box::new(random::RandomSearch),
        "cd" => Box::new(coord_descent::CoordinateDescent),
        "shc" => Box::new(annealing::StochasticHillClimbing::default()),
        "sa" => Box::new(annealing::SimulatedAnnealing::default()),
        "exhaustive" => Box::new(exhaustive::ExhaustiveSearch),
        "cherrypick-x1" => Box::new(bo::FlattenedBo::cherrypick()),
        "cherrypick-x3" => Box::new(bo::IndependentBo::cherrypick()),
        "bilal-x1" => Box::new(bo::FlattenedBo::bilal()),
        "bilal-x3" => Box::new(bo::IndependentBo::bilal()),
        "smac" => Box::new(smac::SmacLite::default()),
        "hyperopt" => Box::new(hyperopt::HyperOptLite::default()),
        "rb" => Box::new(rising_bandits::RisingBandits::default()),
        "rbfopt" => Box::new(rbfopt::RbfOpt),
        "cb-cherrypick" => {
            Box::new(cloudbandit::CloudBandit::new(cloudbandit::Component::CherryPick, 2.0))
        }
        "cb-rbfopt" => Box::new(cloudbandit::CloudBandit::new(cloudbandit::Component::RbfOpt, 2.0)),
        _ => return None,
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::dataset::objective::{LookupObjective, MeasureMode};
    use crate::dataset::OfflineDataset;
    use crate::surrogate::NativeBackend;

    pub fn run_optimizer(
        name: &str,
        ds: &OfflineDataset,
        workload: usize,
        target: Target,
        budget: usize,
        seed: u64,
    ) -> (SearchResult, usize) {
        let opt = by_name(name).unwrap_or_else(|| panic!("unknown optimizer {name}"));
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &ds.domain, target, backend: &backend };
        let mut obj = LookupObjective::new(ds, workload, target, MeasureMode::SingleDraw, seed);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let res = opt.run(&ctx, &mut obj, budget, &mut rng);
        let evals = obj.evals();
        (res, evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::OfflineDataset;

    #[test]
    fn registry_covers_all_names() {
        for name in ALL_OPTIMIZERS {
            assert!(by_name(name).is_some(), "{name} missing from registry");
        }
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn from_history_tracks_best_so_far() {
        let d = Domain::paper();
        let grid = d.full_grid();
        let hist = vec![
            (grid[0].clone(), 5.0),
            (grid[1].clone(), 3.0),
            (grid[2].clone(), 4.0),
            (grid[3].clone(), 1.0),
        ];
        let r = SearchResult::from_history(&hist);
        assert_eq!(r.trace, vec![5.0, 3.0, 3.0, 1.0]);
        assert_eq!(r.best_value, 1.0);
        assert_eq!(r.best_config, grid[3]);
        assert_eq!(r.evals_used, 4);
    }

    /// Every optimizer respects its budget and returns a config whose
    /// observed value matches the history minimum convention.
    #[test]
    fn all_optimizers_respect_budget() {
        let ds = OfflineDataset::generate(3, 3);
        for name in ALL_OPTIMIZERS {
            if name == "exhaustive" {
                continue; // evaluates the full grid by definition
            }
            for budget in [11, 33] {
                let (res, evals) =
                    testutil::run_optimizer(name, &ds, 4, Target::Cost, budget, 17);
                assert!(evals <= budget, "{name} used {evals} > {budget}");
                assert!(evals >= budget.min(8), "{name} underused budget: {evals}");
                assert!(res.best_value.is_finite());
                assert_eq!(res.trace.len(), res.evals_used);
            }
        }
    }

    /// With a generous budget every method should land well below the
    /// domain's mean value (sanity: search actually searches).
    #[test]
    fn optimizers_beat_the_mean_at_large_budget() {
        let ds = OfflineDataset::generate(11, 3);
        let w = 7;
        let mean = ds.random_strategy_value(w, Target::Time);
        let (_, min) = ds.true_min(w, Target::Time);
        for name in ALL_OPTIMIZERS {
            let (res, _) = testutil::run_optimizer(name, &ds, w, Target::Time, 66, 5);
            assert!(
                res.best_value < mean,
                "{name}: best {} not below mean {mean} (min {min})",
                res.best_value
            );
        }
    }
}
