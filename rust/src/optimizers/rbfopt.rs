//! RBFOpt-lite: radial-basis-function black-box optimization [6], [12].
//!
//! A faithful-in-spirit, simplified implementation of RBFOpt's cyclic
//! search strategy over a finite candidate grid: fit the cubic RBF
//! interpolant to all observations, then alternate between *exploitation*
//! (minimize the surrogate) and *exploration* (favour points far from all
//! observations), cycling the exploration weight. The full Gutmann
//! target-value machinery is replaced by this weighted score — documented
//! deviation, see DESIGN.md §Substitutions.
//!
//! The surrogate solve runs through `SearchContext::backend`, i.e. through
//! the AOT PJRT artifact when available.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::{EvalLedger, EvalSink};
use crate::domain::{encode, Config};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Exploration-weight cycle (RBFOpt's search cycles from pure surrogate
/// minimization to pure exploration).
pub const WEIGHT_CYCLE: [f64; 4] = [0.0, 0.2, 0.5, 1.0];

pub struct RbfOptState {
    cands: Vec<Config>,
    /// Encoded candidate grid, one configuration per row.
    enc: Matrix,
    /// Encoded observations, grown one row per step.
    obs_x: Matrix,
    obs_cfg_idx: Vec<usize>,
    ys: Vec<f64>,
    evaluated: Vec<bool>,
    n_init: usize,
    iter: usize,
}

impl RbfOptState {
    pub fn new(ctx: &SearchContext, cands: Vec<Config>) -> RbfOptState {
        assert!(!cands.is_empty());
        let enc = Matrix::from_rows(
            &cands.iter().map(|c| encode(ctx.domain, c)).collect::<Vec<Vec<f64>>>(),
        );
        let evaluated = vec![false; cands.len()];
        let obs_x = Matrix::zeros(0, enc.cols);
        RbfOptState {
            cands,
            enc,
            obs_x,
            obs_cfg_idx: Vec::new(),
            ys: Vec::new(),
            evaluated,
            n_init: 3,
            iter: 0,
        }
    }

    pub fn observations(&self) -> usize {
        self.ys.len()
    }

    pub fn best(&self) -> Option<(Config, f64)> {
        let i = self
            .ys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())?
            .0;
        Some((self.cands[self.obs_cfg_idx[i]].clone(), self.ys[i]))
    }

    fn propose(&mut self, ctx: &SearchContext, rng: &mut Rng) -> usize {
        let unseen: Vec<usize> = (0..self.cands.len()).filter(|&i| !self.evaluated[i]).collect();
        if unseen.is_empty() {
            return rng.usize_below(self.cands.len());
        }
        if self.obs_x.rows < self.n_init {
            return *rng.choice(&unseen);
        }

        let p = ctx.backend.rbf_fit_predict(&self.obs_x, &self.ys, 1e-6, &self.enc);
        let w = WEIGHT_CYCLE[self.iter % WEIGHT_CYCLE.len()];

        // Normalize both signals over the *unseen* candidates.
        let (mut pmin, mut pmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut dmin, mut dmax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &i in &unseen {
            pmin = pmin.min(p.pred[i]);
            pmax = pmax.max(p.pred[i]);
            dmin = dmin.min(p.mindist[i]);
            dmax = dmax.max(p.mindist[i]);
        }
        let prange = (pmax - pmin).max(1e-12);
        let drange = (dmax - dmin).max(1e-12);

        let mut best = (unseen[0], f64::NEG_INFINITY);
        for &i in &unseen {
            // Lower surrogate value is better; larger distance is better.
            let exploit = 1.0 - (p.pred[i] - pmin) / prange;
            let explore = (p.mindist[i] - dmin) / drange;
            let score = (1.0 - w) * exploit + w * explore;
            if score > best.1 {
                best = (i, score);
            }
        }
        best.0
    }

    /// One iteration; None once the sink's budget is exhausted. The sink
    /// is a whole ledger or one arm's shard.
    pub fn step(
        &mut self,
        ctx: &SearchContext,
        sink: &mut dyn EvalSink,
        rng: &mut Rng,
    ) -> Option<f64> {
        if sink.exhausted() {
            return None;
        }
        let i = self.propose(ctx, rng);
        let v = sink.eval(&self.cands[i])?;
        self.iter += 1;
        self.obs_x.push_row(self.enc.row(i));
        self.obs_cfg_idx.push(i);
        self.ys.push(v);
        self.evaluated[i] = true;
        Some(v)
    }
}

/// Standalone RBFOpt over the flattened multi-cloud grid.
pub struct RbfOpt;

impl Optimizer for RbfOpt {
    fn name(&self) -> String {
        "rbfopt".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let mut st = RbfOptState::new(ctx, ctx.domain.full_grid());
        while st.step(ctx, &mut *ledger, rng).is_some() {}
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn never_repeats_until_grid_exhausted() {
        let ds = OfflineDataset::generate(5, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 3, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut ledger = EvalLedger::new(&src, 16);
        let mut st = RbfOptState::new(&ctx, ds.domain.provider_grid(1)); // 16
        let mut rng = Rng::new(2);
        while st.step(&ctx, &mut ledger, &mut rng).is_some() {}
        let mut seen = st.obs_cfg_idx.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn outperforms_first_samples_with_budget() {
        let ds = OfflineDataset::generate(7, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let src = LookupObjective::new(&ds, 12, Target::Time, MeasureMode::Mean, 3);
        let mut ledger = EvalLedger::new(&src, 33);
        let r = RbfOpt.run(&ctx, &mut ledger, &mut Rng::new(4));
        assert_eq!(r.evals_used, 33);
        let mean = ds.random_strategy_value(12, Target::Time);
        assert!(r.best_value < mean);
    }
}
