//! Stochastic hill climbing (SHC) and simulated annealing (SA) — the
//! remaining search baselines evaluated by Bilal et al. [3] (§II-B).
//! Both explore via single-coordinate "neighbour" moves in the
//! hierarchical domain, with an occasional provider jump; SA additionally
//! accepts uphill moves with a temperature-scheduled probability.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::domain::Config;
use crate::util::rng::Rng;

fn random_config(ctx: &SearchContext, rng: &mut Rng) -> Config {
    let provider = rng.usize_below(ctx.domain.provider_count());
    let p = &ctx.domain.providers[provider];
    Config {
        provider,
        choices: p.params.iter().map(|q| rng.usize_below(q.values.len())).collect(),
        nodes: *rng.choice(&ctx.domain.nodes),
    }
}

/// One random neighbour: usually a single-coordinate change within the
/// current provider; with probability `p_jump`, a fresh random config on
/// another provider (the multi-cloud adaptation).
pub fn neighbour(ctx: &SearchContext, cur: &Config, p_jump: f64, rng: &mut Rng) -> Config {
    if ctx.domain.provider_count() > 1 && rng.bool(p_jump) {
        loop {
            let c = random_config(ctx, rng);
            if c.provider != cur.provider {
                return c;
            }
        }
    }
    let p = &ctx.domain.providers[cur.provider];
    let mut c = cur.clone();
    let coord = rng.usize_below(p.params.len() + 1);
    if coord < p.params.len() {
        let k = p.params[coord].values.len();
        if k > 1 {
            let mut v = rng.usize_below(k - 1);
            if v >= c.choices[coord] {
                v += 1;
            }
            c.choices[coord] = v;
        }
    } else {
        let others: Vec<u32> =
            ctx.domain.nodes.iter().copied().filter(|&n| n != cur.nodes).collect();
        if !others.is_empty() {
            c.nodes = *rng.choice(&others);
        }
    }
    c
}

/// Stochastic hill climbing: accept only improving neighbours; restart
/// from a random point after `patience` consecutive rejections.
pub struct StochasticHillClimbing {
    pub p_jump: f64,
    pub patience: usize,
}

impl Default for StochasticHillClimbing {
    fn default() -> Self {
        StochasticHillClimbing { p_jump: 0.15, patience: 8 }
    }
}

impl Optimizer for StochasticHillClimbing {
    fn name(&self) -> String {
        "shc".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let mut cur = random_config(ctx, rng);
        let Some(mut cur_val) = ledger.eval(&cur) else {
            panic!("SHC started with a zero-budget ledger")
        };
        let mut rejections = 0;
        while !ledger.exhausted() {
            if rejections >= self.patience {
                cur = random_config(ctx, rng);
                let Some(v) = ledger.eval(&cur) else { break };
                cur_val = v;
                rejections = 0;
                continue;
            }
            let cand = neighbour(ctx, &cur, self.p_jump, rng);
            let Some(v) = ledger.eval(&cand) else { break };
            if v < cur_val {
                cur = cand;
                cur_val = v;
                rejections = 0;
            } else {
                rejections += 1;
            }
        }
        SearchResult::from_ledger(ledger)
    }
}

/// Simulated annealing with a geometric temperature schedule calibrated
/// to the observed value scale.
pub struct SimulatedAnnealing {
    pub p_jump: f64,
    /// Initial acceptance temperature as a fraction of the first value.
    pub t0_fraction: f64,
    /// Geometric cooling rate per evaluation.
    pub cooling: f64,
}

impl Default for SimulatedAnnealing {
    fn default() -> Self {
        SimulatedAnnealing { p_jump: 0.15, t0_fraction: 0.3, cooling: 0.93 }
    }
}

impl Optimizer for SimulatedAnnealing {
    fn name(&self) -> String {
        "sa".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let mut cur = random_config(ctx, rng);
        let Some(mut cur_val) = ledger.eval(&cur) else {
            panic!("SA started with a zero-budget ledger")
        };
        let mut temp = (cur_val * self.t0_fraction).max(1e-12);
        while !ledger.exhausted() {
            let cand = neighbour(ctx, &cur, self.p_jump, rng);
            let Some(v) = ledger.eval(&cand) else { break };
            let accept = v < cur_val || rng.bool(((cur_val - v) / temp).exp().min(1.0));
            if accept {
                cur = cand;
                cur_val = v;
            }
            temp *= self.cooling;
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    fn run(name: &str, budget: usize, seed: u64) -> (SearchResult, usize) {
        let ds = OfflineDataset::generate(33, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let opt = crate::optimizers::by_name(name).unwrap();
        let src = LookupObjective::new(&ds, 13, Target::Cost, MeasureMode::SingleDraw, seed);
        let mut ledger = EvalLedger::new(&src, budget);
        let r = opt.run(&ctx, &mut ledger, &mut Rng::new(seed));
        let e = ledger.evals();
        (r, e)
    }

    #[test]
    fn shc_and_sa_respect_budget_and_improve() {
        for name in ["shc", "sa"] {
            let (r, evals) = run(name, 44, 5);
            assert_eq!(evals, 44, "{name}");
            assert!(r.best_value <= r.trace[0], "{name}");
        }
    }

    #[test]
    fn neighbour_changes_exactly_one_coordinate_without_jump() {
        let ds = OfflineDataset::generate(34, 2);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let mut rng = Rng::new(3);
        let cur = Config { provider: 2, choices: vec![0, 1, 0], nodes: 3 };
        for _ in 0..200 {
            let n = neighbour(&ctx, &cur, 0.0, &mut rng);
            assert_eq!(n.provider, cur.provider);
            let mut diffs = n.choices.iter().zip(&cur.choices).filter(|(a, b)| a != b).count();
            if n.nodes != cur.nodes {
                diffs += 1;
            }
            assert_eq!(diffs, 1, "{n:?}");
        }
    }

    #[test]
    fn provider_jump_happens_with_probability() {
        let ds = OfflineDataset::generate(35, 2);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let mut rng = Rng::new(4);
        let cur = Config { provider: 0, choices: vec![0, 0], nodes: 2 };
        let jumps =
            (0..1000).filter(|_| neighbour(&ctx, &cur, 0.5, &mut rng).provider != 0).count();
        assert!(jumps > 350 && jumps < 650, "jumps {jumps}");
    }

    #[test]
    fn sa_accepts_some_uphill_moves_early() {
        // Statistical smoke: across seeds, SA's trajectory must contain at
        // least one accepted uphill move (temp > 0) — detectable via the
        // trace being non-strictly-improving yet exploring.
        let (r, _) = run("sa", 30, 11);
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0])); // trace is best-so-far
    }
}
