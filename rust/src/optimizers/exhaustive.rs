//! Exhaustive search: evaluate every configuration across all providers.
//! Guaranteed to find the (observed) optimum, at maximal search expense —
//! the paper uses it as the savings-analysis strawman (Fig. 4, strictly
//! negative savings).

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::Objective;
use crate::util::rng::Rng;

pub struct ExhaustiveSearch;

impl Optimizer for ExhaustiveSearch {
    fn name(&self) -> String {
        "exhaustive".into()
    }

    /// Ignores `budget` (exhaustive by definition); the evaluation order
    /// is shuffled so ties/noise do not systematically favour low ids.
    fn run(
        &self,
        ctx: &SearchContext,
        obj: &mut dyn Objective,
        _budget: usize,
        rng: &mut Rng,
    ) -> SearchResult {
        let mut grid = ctx.domain.full_grid();
        rng.shuffle(&mut grid);
        let mut history = Vec::with_capacity(grid.len());
        for cfg in grid {
            let v = obj.eval(&cfg);
            history.push((cfg, v));
        }
        SearchResult::from_history(&history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn finds_the_true_optimum_in_mean_mode() {
        let ds = OfflineDataset::generate(4, 3);
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &ds.domain, target: Target::Cost, backend: &backend };
        let mut obj = LookupObjective::new(&ds, 11, Target::Cost, MeasureMode::Mean, 1);
        let r = ExhaustiveSearch.run(&ctx, &mut obj, 0, &mut Rng::new(2));
        assert_eq!(r.evals_used, 88);
        let (true_cfg, true_val) = ds.true_min(11, Target::Cost);
        assert_eq!(ds.domain.config_id(&r.best_config), true_cfg);
        assert!((r.best_value - true_val).abs() < 1e-12);
    }
}
