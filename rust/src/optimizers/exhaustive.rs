//! Exhaustive search: evaluate every configuration across all providers.
//! Guaranteed to find the (observed) optimum, at maximal search expense —
//! the paper uses it as the savings-analysis strawman (Fig. 4, strictly
//! negative savings). `provisioned_budget` asks the coordinator for the
//! full grid; the ledger still hard-caps whatever it was actually given.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::util::rng::Rng;

pub struct ExhaustiveSearch;

impl Optimizer for ExhaustiveSearch {
    fn name(&self) -> String {
        "exhaustive".into()
    }

    /// Exhaustive by definition: the nominal budget is ignored in favour
    /// of the full grid (the Fig. 4 strawman always sweeps everything).
    fn provisioned_budget(&self, ctx: &SearchContext, requested: usize) -> usize {
        ctx.domain.size().max(requested)
    }

    /// The evaluation order is shuffled so ties/noise do not
    /// systematically favour low config ids.
    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let mut grid = ctx.domain.full_grid();
        rng.shuffle(&mut grid);
        for cfg in &grid {
            if ledger.eval(cfg).is_none() {
                break;
            }
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn finds_the_true_optimum_in_mean_mode() {
        let ds = OfflineDataset::generate(4, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 11, Target::Cost, MeasureMode::Mean, 1);
        let budget = ExhaustiveSearch.provisioned_budget(&ctx, 0);
        assert_eq!(budget, 88);
        let mut ledger = EvalLedger::new(&src, budget);
        let r = ExhaustiveSearch.run(&ctx, &mut ledger, &mut Rng::new(2));
        assert_eq!(r.evals_used, 88);
        let (true_cfg, true_val) = ds.true_min(11, Target::Cost);
        assert_eq!(ds.domain.config_id(&r.best_config), true_cfg);
        assert!((r.best_value - true_val).abs() < 1e-12);
    }

    #[test]
    fn truncated_by_a_smaller_ledger() {
        let ds = OfflineDataset::generate(4, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 2, Target::Cost, MeasureMode::Mean, 1);
        let mut ledger = EvalLedger::new(&src, 10);
        let r = ExhaustiveSearch.run(&ctx, &mut ledger, &mut Rng::new(3));
        assert_eq!(r.evals_used, 10, "ledger cap wins over the full sweep");
    }
}
