//! CloudBandit (paper §III-D, Algorithm 1) — the paper's contribution.
//!
//! Best-arm identification over cloud providers where pulling an arm runs
//! iterations of an *arbitrary* component black-box optimizer on that
//! provider's configuration space. Differences from Rising Bandits, as
//! the paper lists them:
//!
//! 1. the component BBO is pluggable (CherryPick-BO or RBFOpt-lite here);
//! 2. the per-arm budget grows by a multiplicative factor eta as arms are
//!    eliminated, so surviving providers get exponentially more search;
//! 3. elimination is purely empirical — after each round, the active arm
//!    with the worst best-loss is dropped; no convergence assumptions.
//!
//! Round m (1-based) pulls every active arm `b1 * eta^(m-1)` times; with
//! K = 3 and eta = 2 the total budget is exactly 11*b1, matching the
//! paper's budget grid B in {11, 22, ..., 88}.
//!
//! Output (Algorithm 1 line 11): the best (configuration, nodes) pair *of
//! the surviving provider* — not the globally best observation, which may
//! sit on an eliminated arm.

use super::bo::{BoPreset, BoState};
use super::rbfopt::RbfOptState;
use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::domain::Config;
use crate::util::rng::Rng;

/// Component black-box optimizer choices evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Component {
    CherryPick,
    RbfOpt,
}

/// One arm's component optimizer state.
enum ArmState<'a> {
    Bo(BoState<'a>),
    Rbf(RbfOptState),
}

impl ArmState<'_> {
    fn step(
        &mut self,
        ctx: &SearchContext,
        ledger: &mut EvalLedger,
        rng: &mut Rng,
    ) -> Option<f64> {
        match self {
            ArmState::Bo(s) => s.step(ledger, rng),
            ArmState::Rbf(s) => s.step(ctx, ledger, rng),
        }
    }

    fn best(&self) -> Option<(Config, f64)> {
        match self {
            ArmState::Bo(s) => s.best(),
            ArmState::Rbf(s) => s.best(),
        }
    }
}

pub struct CloudBandit {
    pub component: Component,
    /// Budget growth factor eta (> 1; the paper uses 2).
    pub eta: f64,
}

impl CloudBandit {
    pub fn new(component: Component, eta: f64) -> Self {
        assert!(eta >= 1.0);
        CloudBandit { component, eta }
    }

    fn make_arm<'a>(&self, ctx: &SearchContext<'a>, provider: usize) -> ArmState<'a> {
        let grid = ctx.domain.provider_grid(provider);
        match self.component {
            Component::CherryPick => {
                // Fewer init points than standalone CherryPick: the first
                // rounds may only have 1-2 pulls per arm.
                ArmState::Bo(BoState::new(
                    ctx,
                    grid,
                    BoPreset { n_init: 2, ..BoPreset::cherrypick() },
                ))
            }
            Component::RbfOpt => ArmState::Rbf(RbfOptState::new(ctx, grid)),
        }
    }
}

/// Initial per-arm budget b1 such that the Algorithm-1 schedule
/// sum_{m=1..K} (K-m+1) * b1 * eta^(m-1) stays within `total`. Returns at
/// least 1.
pub fn b1_for_budget(total: usize, k: usize, eta: f64) -> usize {
    let unit: f64 = (1..=k).map(|m| (k - m + 1) as f64 * eta.powi(m as i32 - 1)).sum();
    ((total as f64 / unit).floor() as usize).max(1)
}

impl Optimizer for CloudBandit {
    fn name(&self) -> String {
        match self.component {
            Component::CherryPick => "cb-cherrypick".into(),
            Component::RbfOpt => "cb-rbfopt".into(),
        }
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let k = ctx.domain.provider_count();
        let b1 = b1_for_budget(ledger.remaining(), k, self.eta);
        let mut arms: Vec<Option<ArmState>> =
            (0..k).map(|p| Some(self.make_arm(ctx, p))).collect();
        let mut losses: Vec<f64> = vec![f64::INFINITY; k];
        let mut b_m = b1 as f64;

        'schedule: for _round in 0..k {
            let active: Vec<usize> = (0..k).filter(|&a| arms[a].is_some()).collect();
            if active.is_empty() {
                break;
            }
            // Pull every active arm b_m times (budget permitting).
            for &a in &active {
                let arm = arms[a].as_mut().unwrap();
                for _ in 0..(b_m.round() as usize) {
                    if arm.step(ctx, ledger, rng).is_none() {
                        if let Some((_, v)) = arm.best() {
                            losses[a] = v;
                        }
                        break 'schedule;
                    }
                }
                if let Some((_, v)) = arm.best() {
                    losses[a] = v;
                }
            }
            // Eliminate the worst active arm (not in the final round).
            if active.len() > 1 {
                let worst = *active
                    .iter()
                    .max_by(|&&x, &&y| losses[x].partial_cmp(&losses[y]).unwrap())
                    .unwrap();
                arms[worst] = None;
            }
            b_m *= self.eta;
        }

        // Spend any integer-rounding leftover on the surviving arm.
        let winner_idx = (0..k)
            .filter(|&a| arms[a].is_some())
            .min_by(|&x, &y| losses[x].partial_cmp(&losses[y]).unwrap())
            .expect("CloudBandit finished with no arms");
        while !ledger.exhausted() {
            let arm = arms[winner_idx].as_mut().unwrap();
            if arm.step(ctx, ledger, rng).is_none() {
                break;
            }
        }

        let (best_config, best_value) =
            arms[winner_idx].as_ref().unwrap().best().expect("winner arm never pulled");
        let mut result = SearchResult::from_ledger(ledger);
        result.best_config = best_config;
        result.best_value = best_value;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn b1_matches_paper_grid() {
        // K=3, eta=2: unit = 3 + 4 + 4 = 11.
        assert_eq!(b1_for_budget(11, 3, 2.0), 1);
        assert_eq!(b1_for_budget(33, 3, 2.0), 3);
        assert_eq!(b1_for_budget(88, 3, 2.0), 8);
        assert_eq!(b1_for_budget(5, 3, 2.0), 1); // floor, min 1
    }

    fn run_cb(component: Component, budget: usize, seed: u64) -> (SearchResult, Vec<(usize, f64)>) {
        let ds = OfflineDataset::generate(31, 3);
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &ds.domain, target: Target::Cost, backend: &backend };
        let mut src = LookupObjective::new(&ds, 22, Target::Cost, MeasureMode::SingleDraw, seed);
        let mut ledger = EvalLedger::new(&mut src, budget);
        let r = CloudBandit::new(component, 2.0).run(&ctx, &mut ledger, &mut Rng::new(seed));
        let prov = ledger.history().iter().map(|(c, v)| (c.provider, *v)).collect();
        (r, prov)
    }

    #[test]
    fn uses_full_budget_exactly() {
        for component in [Component::CherryPick, Component::RbfOpt] {
            for budget in [11, 22, 33] {
                let (r, hist) = run_cb(component, budget, 9);
                assert_eq!(hist.len(), budget, "{component:?} B={budget}");
                assert_eq!(r.evals_used, budget);
            }
        }
    }

    #[test]
    fn elimination_schedule_concentrates_late_pulls() {
        let (_, hist) = run_cb(Component::RbfOpt, 33, 5);
        // Round 1: 3 providers x 3 pulls = 9 evals over all 3 providers.
        let early: std::collections::HashSet<usize> =
            hist[..9].iter().map(|&(p, _)| p).collect();
        assert_eq!(early.len(), 3, "round 1 touches all providers");
        // Final 12 pulls (round 3 + leftovers): exactly one provider.
        let late: std::collections::HashSet<usize> =
            hist[hist.len() - 12..].iter().map(|&(p, _)| p).collect();
        assert_eq!(late.len(), 1, "final round is single-provider: {late:?}");
    }

    #[test]
    fn returns_config_from_surviving_provider() {
        let (r, hist) = run_cb(Component::CherryPick, 22, 7);
        let last_provider = hist.last().unwrap().0;
        assert_eq!(
            r.best_config.provider, last_provider,
            "output must come from the surviving arm"
        );
    }

    #[test]
    fn works_with_budget_below_schedule_unit() {
        // B < 11: b1 = 1, schedule truncated by the ledger's cap.
        let (r, hist) = run_cb(Component::RbfOpt, 7, 3);
        assert_eq!(hist.len(), 7);
        assert!(r.best_value.is_finite());
    }
}
