//! CloudBandit (paper §III-D, Algorithm 1) — the paper's contribution.
//!
//! Best-arm identification over cloud providers where pulling an arm runs
//! iterations of an *arbitrary* component black-box optimizer on that
//! provider's configuration space. Differences from Rising Bandits, as
//! the paper lists them:
//!
//! 1. the component BBO is pluggable (CherryPick-BO or RBFOpt-lite here);
//! 2. the per-arm budget grows by a multiplicative factor eta as arms are
//!    eliminated, so surviving providers get exponentially more search;
//! 3. elimination is purely empirical — after each round, the active arm
//!    with the worst best-loss is dropped; no convergence assumptions.
//!
//! Round m (1-based) pulls every active arm `b1 * eta^(m-1)` times; with
//! K = 3 and eta = 2 the total budget is exactly 11*b1, matching the
//! paper's budget grid B in {11, 22, ..., 88}.
//!
//! **Parallel arm execution.** Arm pulls within a round are independent
//! (disjoint provider grids, per-arm component state), so each round runs
//! all active arms concurrently on the persistent
//! `util::threadpool::WorkerTeam` (no per-round thread spawns) when
//! `SearchContext::arm_workers > 1`. Each arm owns a [`LedgerShard`] of
//! the trial ledger (budget drawn from the shared atomic pool) plus its
//! own component state and forked RNG; after the round, shards merge back
//! in canonical arm order. Round quotas are fixed *before* the round in
//! arm order, so a truncated budget lands on the same arms regardless of
//! thread scheduling — parallel runs are bit-identical to sequential.
//!
//! Output (Algorithm 1 line 11): the best (configuration, nodes) pair *of
//! the surviving provider* — not the globally best observation, which may
//! sit on an eliminated arm.

use super::bo::{BoPreset, BoState};
use super::rbfopt::RbfOptState;
use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::{EvalLedger, EvalSink, LedgerShard};
use crate::domain::Config;
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map_owned;

/// Component black-box optimizer choices evaluated in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Component {
    CherryPick,
    RbfOpt,
}

/// One arm's component optimizer state.
enum ArmState<'a> {
    Bo(BoState<'a>),
    Rbf(RbfOptState),
}

impl ArmState<'_> {
    fn step(
        &mut self,
        ctx: &SearchContext,
        sink: &mut dyn EvalSink,
        rng: &mut Rng,
    ) -> Option<f64> {
        match self {
            ArmState::Bo(s) => s.step(sink, rng),
            ArmState::Rbf(s) => s.step(ctx, sink, rng),
        }
    }

    fn best(&self) -> Option<(Config, f64)> {
        match self {
            ArmState::Bo(s) => s.best(),
            ArmState::Rbf(s) => s.best(),
        }
    }
}

/// Everything one arm owns for the lifetime of a trial: component state,
/// ledger shard, decorrelated RNG, and its scheduling/elimination status.
/// Moved onto a worker thread each round and back.
struct ArmTask<'c, 'l> {
    arm: ArmState<'c>,
    shard: LedgerShard<'l>,
    rng: Rng,
    /// Pulls granted for the current round (set before the round starts).
    quota: usize,
    /// Best observed loss on this arm so far.
    loss: f64,
    /// Still in the tournament (not eliminated).
    alive: bool,
}

pub struct CloudBandit {
    pub component: Component,
    /// Budget growth factor eta (> 1; the paper uses 2).
    pub eta: f64,
}

impl CloudBandit {
    pub fn new(component: Component, eta: f64) -> Self {
        assert!(eta >= 1.0);
        CloudBandit { component, eta }
    }

    fn make_arm<'c>(&self, ctx: &SearchContext<'c>, provider: usize) -> ArmState<'c> {
        let grid = ctx.domain.provider_grid(provider);
        match self.component {
            Component::CherryPick => {
                // Fewer init points than standalone CherryPick: the first
                // rounds may only have 1-2 pulls per arm.
                ArmState::Bo(BoState::new(
                    ctx,
                    grid,
                    BoPreset { n_init: 2, ..BoPreset::cherrypick() },
                ))
            }
            Component::RbfOpt => ArmState::Rbf(RbfOptState::new(ctx, grid)),
        }
    }
}

/// Initial per-arm budget b1 such that the Algorithm-1 schedule
/// sum_{m=1..K} (K-m+1) * b1 * eta^(m-1) stays within `total`. Returns at
/// least 1.
pub fn b1_for_budget(total: usize, k: usize, eta: f64) -> usize {
    let unit: f64 = (1..=k).map(|m| (k - m + 1) as f64 * eta.powi(m as i32 - 1)).sum();
    ((total as f64 / unit).floor() as usize).max(1)
}

/// Run one round's granted pulls on an arm, then refresh its loss.
fn pull_arm(ctx: &SearchContext, task: &mut ArmTask) {
    task.shard.grant(task.quota);
    for _ in 0..task.quota {
        if task.arm.step(ctx, &mut task.shard, &mut task.rng).is_none() {
            break;
        }
    }
    if let Some((_, v)) = task.arm.best() {
        task.loss = v;
    }
}

impl Optimizer for CloudBandit {
    fn name(&self) -> String {
        match self.component {
            Component::CherryPick => "cb-cherrypick".into(),
            Component::RbfOpt => "cb-rbfopt".into(),
        }
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        // One arm per *available* provider: a revoked provider (dynamic
        // markets) gets no arm at all, so the tournament re-pulls its
        // budget across the survivors instead of wasting rounds on
        // capacity that cannot host the workload. With nothing revoked
        // this is `0..provider_count()` — the static behaviour, and the
        // per-arm RNG forks stay keyed by provider id either way.
        let providers = ctx.available_providers();
        let k = providers.len();
        let b1 = b1_for_budget(ledger.remaining(), k, self.eta);
        let mut tasks: Vec<ArmTask> = ledger
            .shard(k, 0)
            .into_iter()
            .zip(&providers)
            .map(|(shard, &p)| ArmTask {
                arm: self.make_arm(ctx, p),
                shard,
                rng: rng.fork(p as u64),
                quota: 0,
                loss: f64::INFINITY,
                alive: true,
            })
            .collect();

        let mut b_m = b1 as f64;
        for _round in 0..k {
            // Fix round quotas in arm order before any pull: the schedule
            // grants b_m pulls per active arm, truncated front-to-back by
            // the remaining budget (sequential semantics — scheduling
            // cannot shift budget between arms).
            let pulls = b_m.round() as usize;
            let mut left = ledger.remaining();
            for t in tasks.iter_mut() {
                t.quota = if t.alive { pulls.min(left) } else { 0 };
                left -= t.quota;
            }
            // Pull every active arm, concurrently when workers allow.
            tasks = parallel_map_owned(tasks, ctx.arm_workers, |mut t| {
                pull_arm(ctx, &mut t);
                t
            });
            // Deterministic merge in canonical arm order.
            for t in tasks.iter_mut() {
                ledger.merge(&mut t.shard);
            }
            // Eliminate the worst active arm (not in the final round).
            let active: Vec<usize> = (0..k).filter(|&a| tasks[a].alive).collect();
            if active.len() > 1 {
                let worst = *active
                    .iter()
                    .max_by(|&&x, &&y| tasks[x].loss.partial_cmp(&tasks[y].loss).unwrap())
                    .unwrap();
                tasks[worst].alive = false;
            }
            b_m *= self.eta;
        }

        // Spend any integer-rounding leftover on the surviving arm.
        let winner = (0..k)
            .filter(|&a| tasks[a].alive)
            .min_by(|&x, &y| tasks[x].loss.partial_cmp(&tasks[y].loss).unwrap())
            .expect("CloudBandit finished with no arms");
        {
            let t = &mut tasks[winner];
            t.shard.grant(ledger.remaining());
            while t.arm.step(ctx, &mut t.shard, &mut t.rng).is_some() {}
            ledger.merge(&mut t.shard);
        }

        // A winner that never completed a pull (a budget truncated to
        // near-nothing by cancellation/revocation) has no arm-local best;
        // fall back to the ledger's global best instead of panicking —
        // degradation, not a crash.
        let mut result = SearchResult::from_ledger(ledger);
        if let Some((best_config, best_value)) = tasks[winner].arm.best() {
            result.best_config = best_config;
            result.best_value = best_value;
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn b1_matches_paper_grid() {
        // K=3, eta=2: unit = 3 + 4 + 4 = 11.
        assert_eq!(b1_for_budget(11, 3, 2.0), 1);
        assert_eq!(b1_for_budget(33, 3, 2.0), 3);
        assert_eq!(b1_for_budget(88, 3, 2.0), 8);
        assert_eq!(b1_for_budget(5, 3, 2.0), 1); // floor, min 1
    }

    fn run_cb_workers(
        component: Component,
        budget: usize,
        seed: u64,
        workers: usize,
    ) -> (SearchResult, Vec<(usize, f64)>, f64) {
        let ds = OfflineDataset::generate(31, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend).with_arm_workers(workers);
        let src = LookupObjective::new(&ds, 22, Target::Cost, MeasureMode::SingleDraw, seed);
        let mut ledger = EvalLedger::new(&src, budget);
        let r = CloudBandit::new(component, 2.0).run(&ctx, &mut ledger, &mut Rng::new(seed));
        let prov = ledger.history().iter().map(|(c, v)| (c.provider, *v)).collect();
        let expense = ledger.total_expense();
        (r, prov, expense)
    }

    fn run_cb(component: Component, budget: usize, seed: u64) -> (SearchResult, Vec<(usize, f64)>) {
        let (r, prov, _) = run_cb_workers(component, budget, seed, 1);
        (r, prov)
    }

    #[test]
    fn uses_full_budget_exactly() {
        for component in [Component::CherryPick, Component::RbfOpt] {
            for budget in [11, 22, 33] {
                let (r, hist) = run_cb(component, budget, 9);
                assert_eq!(hist.len(), budget, "{component:?} B={budget}");
                assert_eq!(r.evals_used, budget);
            }
        }
    }

    #[test]
    fn elimination_schedule_concentrates_late_pulls() {
        let (_, hist) = run_cb(Component::RbfOpt, 33, 5);
        // Round 1: 3 providers x 3 pulls = 9 evals over all 3 providers.
        let early: std::collections::HashSet<usize> =
            hist[..9].iter().map(|&(p, _)| p).collect();
        assert_eq!(early.len(), 3, "round 1 touches all providers");
        // Final 12 pulls (round 3 + leftovers): exactly one provider.
        let late: std::collections::HashSet<usize> =
            hist[hist.len() - 12..].iter().map(|&(p, _)| p).collect();
        assert_eq!(late.len(), 1, "final round is single-provider: {late:?}");
    }

    #[test]
    fn returns_config_from_surviving_provider() {
        let (r, hist) = run_cb(Component::CherryPick, 22, 7);
        let last_provider = hist.last().unwrap().0;
        assert_eq!(
            r.best_config.provider, last_provider,
            "output must come from the surviving arm"
        );
    }

    #[test]
    fn works_with_budget_below_schedule_unit() {
        // B < 11: b1 = 1, schedule truncated by the shared budget pool.
        let (r, hist) = run_cb(Component::RbfOpt, 7, 3);
        assert_eq!(hist.len(), 7);
        assert!(r.best_value.is_finite());
    }

    /// The tentpole guarantee: parallel arm execution is bit-identical to
    /// sequential — same history (configs, values, order), same best,
    /// same expense, same trace — for both components across budgets and
    /// seeds, including budgets that truncate the schedule mid-round.
    #[test]
    fn parallel_arms_are_bit_identical_to_sequential() {
        for component in [Component::CherryPick, Component::RbfOpt] {
            for budget in [7, 11, 22, 33] {
                for seed in [1u64, 9] {
                    let (r1, h1, e1) = run_cb_workers(component, budget, seed, 1);
                    for workers in [2usize, 4] {
                        let (rw, hw, ew) = run_cb_workers(component, budget, seed, workers);
                        assert_eq!(
                            h1, hw,
                            "{component:?} B={budget} seed={seed} workers={workers}: history diverged"
                        );
                        assert_eq!(r1.best_config, rw.best_config);
                        assert_eq!(r1.best_value.to_bits(), rw.best_value.to_bits());
                        assert_eq!(r1.trace, rw.trace);
                        assert_eq!(e1.to_bits(), ew.to_bits(), "expense diverged");
                    }
                }
            }
        }
    }

    /// Dynamic markets: a revoked provider gets no arm, the tournament
    /// re-pulls the whole budget across surviving providers, and the
    /// returned config never lands on revoked capacity.
    #[test]
    fn revoked_providers_get_no_pulls() {
        let ds = OfflineDataset::generate(31, 3);
        let backend = NativeBackend;
        for revoked in [vec![0usize], vec![2], vec![0, 2]] {
            let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend)
                .with_revoked(revoked.clone());
            let src = LookupObjective::new(&ds, 22, Target::Cost, MeasureMode::SingleDraw, 9);
            let mut ledger = EvalLedger::new(&src, 22);
            let r =
                CloudBandit::new(Component::RbfOpt, 2.0).run(&ctx, &mut ledger, &mut Rng::new(9));
            assert_eq!(ledger.evals(), 22, "full budget re-pulled across survivors");
            assert!(
                ledger.history().iter().all(|(c, _)| !revoked.contains(&c.provider)),
                "revoked {revoked:?} must receive zero pulls"
            );
            assert!(!revoked.contains(&r.best_config.provider));
        }
    }

    /// Budgets not on the 11·b1 grid truncate a round; the front-to-back
    /// quota rule must keep the truncation on the same arms in parallel.
    #[test]
    fn truncated_rounds_stay_deterministic_under_parallelism() {
        for budget in [1usize, 2, 5, 13, 29] {
            let (_, h1, _) = run_cb_workers(Component::CherryPick, budget, 3, 1);
            let (_, h4, _) = run_cb_workers(Component::CherryPick, budget, 3, 4);
            assert_eq!(h1, h4, "B={budget}");
            assert_eq!(h1.len(), budget);
        }
    }
}
