//! Random search: configurations drawn uniformly with replacement from
//! the flattened multi-cloud grid (the paper's RS baseline, §IV-B),
//! until the ledger's budget is spent.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::util::rng::Rng;

pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> String {
        "rs".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let grid = ctx.domain.full_grid();
        while ledger.eval(rng.choice(&grid)).is_some() {}
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::optimizers::SearchContext;
    use crate::surrogate::NativeBackend;

    #[test]
    fn uses_exactly_the_budget_and_is_seed_deterministic() {
        let ds = OfflineDataset::generate(1, 2);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let run = |seed| {
            let src = LookupObjective::new(&ds, 0, Target::Cost, MeasureMode::Mean, 5);
            let mut ledger = EvalLedger::new(&src, 22);
            RandomSearch.run(&ctx, &mut ledger, &mut Rng::new(seed))
        };
        let a = run(9);
        let b = run(9);
        let c = run(10);
        assert_eq!(a.evals_used, 22);
        assert_eq!(a.best_value, b.best_value);
        assert_eq!(a.best_config, b.best_config);
        // Different seed explores differently (overwhelmingly likely).
        assert!(a.trace != c.trace || a.best_config != c.best_config);
    }

    #[test]
    fn trace_is_monotone_nonincreasing() {
        let ds = OfflineDataset::generate(2, 2);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let src = LookupObjective::new(&ds, 3, Target::Time, MeasureMode::SingleDraw, 7);
        let mut ledger = EvalLedger::new(&src, 40);
        let r = RandomSearch.run(&ctx, &mut ledger, &mut Rng::new(1));
        assert!(r.trace.windows(2).all(|w| w[1] <= w[0]));
    }
}
