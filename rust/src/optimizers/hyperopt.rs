//! HyperOpt-lite: hierarchical Tree-structured Parzen Estimator [2].
//!
//! Models the hierarchical domain as a graph-structured generative
//! process, exactly as HyperOpt expresses conditional search spaces: first
//! the provider choice, then — conditioned on the provider — each of its
//! categorical parameters, plus the shared node count. Each node of the
//! graph carries an l(x)/g(x) Parzen pair (surrogate::tpe); proposals are
//! sampled from the good densities and ranked by the likelihood ratio.
//!
//! Deliberately allows repeated configurations (HyperOpt does too): the
//! paper attributes HyperOpt's gap to SMAC to precisely this.

use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::domain::Config;
use crate::surrogate::tpe::{split_good_bad, TpePair};
use crate::util::rng::Rng;

pub struct HyperOptLite {
    pub n_init: usize,
    /// Fraction of observations considered "good".
    pub gamma: f64,
    /// Candidates sampled from l(x) per iteration.
    pub n_candidates: usize,
    /// Laplace smoothing for the categorical densities.
    pub alpha: f64,
}

impl Default for HyperOptLite {
    fn default() -> Self {
        HyperOptLite { n_init: 5, gamma: 0.25, n_candidates: 24, alpha: 1.0 }
    }
}

fn random_config(ctx: &SearchContext, rng: &mut Rng) -> Config {
    let provider = rng.usize_below(ctx.domain.provider_count());
    let p = &ctx.domain.providers[provider];
    Config {
        provider,
        choices: p.params.iter().map(|q| rng.usize_below(q.values.len())).collect(),
        nodes: *rng.choice(&ctx.domain.nodes),
    }
}

impl HyperOptLite {
    fn propose(
        &self,
        ctx: &SearchContext,
        history: &[(Config, f64)],
        rng: &mut Rng,
    ) -> Config {
        let ys: Vec<f64> = history.iter().map(|(_, v)| *v).collect();
        let (good, bad) = split_good_bad(&ys, self.gamma);

        let k = ctx.domain.provider_count();
        let providers_of = |idx: &[usize]| -> Vec<usize> {
            idx.iter().map(|&i| history[i].0.provider).collect()
        };
        let provider_pair =
            TpePair::new(k, &providers_of(&good), &providers_of(&bad), self.alpha);

        let n_values = ctx.domain.nodes.len();
        let node_idx_of = |idx: &[usize]| -> Vec<usize> {
            idx.iter()
                .map(|&i| {
                    ctx.domain.nodes.iter().position(|&n| n == history[i].0.nodes).unwrap()
                })
                .collect()
        };
        let nodes_pair =
            TpePair::new(n_values, &node_idx_of(&good), &node_idx_of(&bad), self.alpha);

        // Conditional parameter pairs per provider, built lazily.
        let mut best: Option<(Config, f64)> = None;
        for _ in 0..self.n_candidates {
            let provider = provider_pair.sample_good(rng);
            let pspace = &ctx.domain.providers[provider];
            let mut score = provider_pair.ratio(provider).ln();

            let good_p: Vec<usize> =
                good.iter().copied().filter(|&i| history[i].0.provider == provider).collect();
            let bad_p: Vec<usize> =
                bad.iter().copied().filter(|&i| history[i].0.provider == provider).collect();

            let mut choices = Vec::with_capacity(pspace.params.len());
            for (qi, q) in pspace.params.iter().enumerate() {
                let choice_of = |idx: &[usize]| -> Vec<usize> {
                    idx.iter().map(|&i| history[i].0.choices[qi]).collect()
                };
                let pair = TpePair::new(
                    q.values.len(),
                    &choice_of(&good_p),
                    &choice_of(&bad_p),
                    self.alpha,
                );
                let v = pair.sample_good(rng);
                score += pair.ratio(v).ln();
                choices.push(v);
            }

            let node_i = nodes_pair.sample_good(rng);
            score += nodes_pair.ratio(node_i).ln();

            let cfg = Config { provider, choices, nodes: ctx.domain.nodes[node_i] };
            if best.as_ref().map(|(_, s)| score > *s).unwrap_or(true) {
                best = Some((cfg, score));
            }
        }
        best.expect("n_candidates > 0").0
    }
}

impl Optimizer for HyperOptLite {
    fn name(&self) -> String {
        "hyperopt".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        while !ledger.exhausted() {
            let cfg = if ledger.evals() < self.n_init {
                random_config(ctx, rng)
            } else {
                self.propose(ctx, ledger.history(), rng)
            };
            if ledger.eval(&cfg).is_none() {
                break;
            }
        }
        SearchResult::from_ledger(ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn proposals_are_valid_configs() {
        let ds = OfflineDataset::generate(12, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 14, Target::Cost, MeasureMode::SingleDraw, 2);
        let mut ledger = EvalLedger::new(&src, 30);
        HyperOptLite::default().run(&ctx, &mut ledger, &mut Rng::new(3));
        for (cfg, _) in ledger.history() {
            // config_id panics on invalid configs; also checks nodes value.
            let _ = ds.domain.config_id(cfg);
        }
        assert_eq!(ledger.history().len(), 30);
    }

    #[test]
    fn concentrates_on_the_better_provider() {
        // After enough iterations the provider density should favour the
        // provider containing the optimum.
        let ds = OfflineDataset::generate(13, 3);
        let backend = NativeBackend;
        let w = 3;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let (best_cfg_id, _) = ds.true_min(w, Target::Cost);
        let best_provider = ds.domain.full_grid()[best_cfg_id].provider;
        let src = LookupObjective::new(&ds, w, Target::Cost, MeasureMode::SingleDraw, 4);
        let mut ledger = EvalLedger::new(&src, 60);
        HyperOptLite::default().run(&ctx, &mut ledger, &mut Rng::new(5));
        let late = &ledger.history()[30..];
        let hits = late.iter().filter(|(c, _)| c.provider == best_provider).count();
        assert!(hits * 2 > late.len(), "only {hits}/{} late samples on best provider", late.len());
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let ds = OfflineDataset::generate(14, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Time, &backend);
        let run = |seed| {
            let src = LookupObjective::new(&ds, 8, Target::Time, MeasureMode::SingleDraw, 6);
            let mut ledger = EvalLedger::new(&src, 25);
            HyperOptLite::default().run(&ctx, &mut ledger, &mut Rng::new(seed))
        };
        let (a, b) = (run(7), run(7));
        assert_eq!(a.best_config, b.best_config);
        assert_eq!(a.trace, b.trace);
    }
}
