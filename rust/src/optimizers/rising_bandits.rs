//! Rising Bandits [22], adapted to multi-cloud configuration (§III-C).
//!
//! Best-arm identification where each arm is a cloud provider and each
//! pull runs one iteration of a GP-BO component optimizer on that
//! provider. RB's elimination rule extrapolates each arm's best-loss
//! curve: assuming diminishing returns, an arm's *optimistic* final value
//! is its current best minus the current improvement rate times the
//! remaining pulls; its *pessimistic* value is its current best. Arm k is
//! eliminated when its optimistic bound is worse than some other arm's
//! pessimistic bound.
//!
//! The paper warns the diminishing-returns assumption need not hold in
//! clouds — and indeed RB degrades at large budgets (Fig. 3), which this
//! implementation reproduces.

use super::bo::{BoPreset, BoState};
use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::EvalLedger;
use crate::util::rng::Rng;

pub struct RisingBandits {
    /// Window (pulls) for estimating the improvement slope.
    pub slope_window: usize,
    /// Minimum pulls per arm before elimination is considered.
    pub min_pulls: usize,
}

impl Default for RisingBandits {
    fn default() -> Self {
        RisingBandits { slope_window: 3, min_pulls: 4 }
    }
}

struct Arm<'a> {
    state: BoState<'a>,
    /// Best-so-far after each pull.
    curve: Vec<f64>,
    active: bool,
}

impl Arm<'_> {
    fn best_val(&self) -> f64 {
        *self.curve.last().unwrap_or(&f64::INFINITY)
    }

    /// Improvement per pull over the trailing window (>= 0).
    fn slope(&self, window: usize) -> f64 {
        let n = self.curve.len();
        if n < 2 {
            return f64::INFINITY; // unknown: maximal optimism
        }
        let w = window.min(n - 1);
        ((self.curve[n - 1 - w] - self.curve[n - 1]) / w as f64).max(0.0)
    }

    /// Optimistic final value given `remaining` further pulls.
    fn lower_bound(&self, window: usize, remaining: usize) -> f64 {
        let s = self.slope(window);
        if s.is_infinite() {
            return f64::NEG_INFINITY;
        }
        self.best_val() - s * remaining as f64
    }
}

impl Optimizer for RisingBandits {
    fn name(&self) -> String {
        "rb".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let k = ctx.domain.provider_count();
        let mut arms: Vec<Arm> = (0..k)
            .map(|p| Arm {
                // [22] gives no BO details; default GP-BO (EI), like our
                // CherryPick preset but with fewer init points per arm.
                state: BoState::new(
                    ctx,
                    ctx.domain.provider_grid(p),
                    BoPreset { n_init: 2, ..BoPreset::cherrypick() },
                ),
                curve: Vec::new(),
                active: true,
            })
            .collect();

        'outer: while !ledger.exhausted() {
            // Round-robin over active arms.
            for a in 0..k {
                if !arms[a].active {
                    continue;
                }
                let Some(v) = arms[a].state.step(ledger, rng) else { break 'outer };
                let best = arms[a].best_val().min(v);
                arms[a].curve.push(best);
            }

            // Elimination pass (keep at least one arm).
            let active_count = arms.iter().filter(|a| a.active).count();
            if active_count > 1 {
                let remaining_rounds = ledger.remaining() / active_count.max(1);
                let mut to_kill: Option<usize> = None;
                for i in 0..k {
                    if !arms[i].active || arms[i].curve.len() < self.min_pulls {
                        continue;
                    }
                    let lb_i = arms[i].lower_bound(self.slope_window, remaining_rounds);
                    // Another active arm already guarantees a better value.
                    let dominated = (0..k).any(|j| {
                        j != i && arms[j].active && arms[j].curve.len() >= self.min_pulls
                            && arms[j].best_val() < lb_i
                    });
                    if dominated {
                        to_kill = Some(i);
                        break;
                    }
                }
                if let Some(i) = to_kill {
                    arms[i].active = false;
                }
            }
        }

        // Output: best pair of the best active arm.
        let winner = arms
            .iter()
            .filter(|a| a.active && !a.curve.is_empty())
            .min_by(|x, y| x.best_val().partial_cmp(&y.best_val()).unwrap())
            .expect("no active arm with observations");
        let (cfg, val) = winner.state.best().unwrap();
        let mut result = SearchResult::from_ledger(ledger);
        result.best_config = cfg;
        result.best_value = val;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn slope_and_bounds() {
        let d = crate::domain::Domain::paper();
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &d, target: Target::Cost, backend: &backend };
        let mk = |curve: Vec<f64>| Arm {
            state: BoState::new(&ctx, d.provider_grid(0), BoPreset::cherrypick()),
            curve,
            active: true,
        };
        let flat = mk(vec![5.0, 5.0, 5.0, 5.0]);
        assert_eq!(flat.slope(3), 0.0);
        assert_eq!(flat.lower_bound(3, 100), 5.0);
        let falling = mk(vec![10.0, 8.0, 6.0, 4.0]);
        assert!((falling.slope(3) - 2.0).abs() < 1e-12);
        assert!((falling.lower_bound(3, 2) - 0.0).abs() < 1e-12);
        let fresh = mk(vec![7.0]);
        assert_eq!(fresh.lower_bound(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn runs_within_budget_and_returns_valid_config() {
        let ds = OfflineDataset::generate(21, 3);
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &ds.domain, target: Target::Cost, backend: &backend };
        let mut src = LookupObjective::new(&ds, 17, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut ledger = EvalLedger::new(&mut src, 22);
        let r = RisingBandits::default().run(&ctx, &mut ledger, &mut Rng::new(2));
        assert!(ledger.evals() <= 22);
        let _ = ds.domain.config_id(&r.best_config);
    }

    #[test]
    fn eliminates_a_hopeless_arm_eventually() {
        // Use a real dataset but check that by the end at most 2 arms keep
        // being pulled when one provider is clearly dominated.
        let ds = OfflineDataset::generate(22, 3);
        let backend = NativeBackend;
        let ctx = SearchContext { domain: &ds.domain, target: Target::Cost, backend: &backend };
        let mut src = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::SingleDraw, 3);
        let mut ledger = EvalLedger::new(&mut src, 66);
        RisingBandits::default().run(&ctx, &mut ledger, &mut Rng::new(4));
        // Last 9 evaluations: how many distinct providers still pulled?
        let h = ledger.history();
        let tail = &h[h.len() - 9..];
        let mut provs: Vec<usize> = tail.iter().map(|(c, _)| c.provider).collect();
        provs.sort_unstable();
        provs.dedup();
        assert!(provs.len() <= 3); // smoke: structure holds (often < 3)
    }
}
