//! Rising Bandits [22], adapted to multi-cloud configuration (§III-C).
//!
//! Best-arm identification where each arm is a cloud provider and each
//! pull runs one iteration of a GP-BO component optimizer on that
//! provider. RB's elimination rule extrapolates each arm's best-loss
//! curve: assuming diminishing returns, an arm's *optimistic* final value
//! is its current best minus the current improvement rate times the
//! remaining pulls; its *pessimistic* value is its current best. Arm k is
//! eliminated when its optimistic bound is worse than some other arm's
//! pessimistic bound.
//!
//! Like CloudBandit, each round-robin sweep pulls all active arms
//! concurrently when `SearchContext::arm_workers > 1`: every arm owns a
//! [`LedgerShard`] (drawing from the shared atomic budget pool), its own
//! GP session and forked RNG; shards merge back in arm order after each
//! sweep, so parallel runs are bit-identical to sequential ones. Sweeps
//! run on the persistent process [`WorkerTeam`] — one channel send per
//! arm instead of a thread spawn/join per sweep, which matters here
//! because RB fans out once per single-pull sweep.
//!
//! [`WorkerTeam`]: crate::util::threadpool::WorkerTeam
//!
//! The paper warns the diminishing-returns assumption need not hold in
//! clouds — and indeed RB degrades at large budgets (Fig. 3), which this
//! implementation reproduces.

use super::bo::{BoPreset, BoState};
use super::{Optimizer, SearchContext, SearchResult};
use crate::dataset::objective::{EvalLedger, LedgerShard};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map_owned;

pub struct RisingBandits {
    /// Window (pulls) for estimating the improvement slope.
    pub slope_window: usize,
    /// Minimum pulls per arm before elimination is considered.
    pub min_pulls: usize,
}

impl Default for RisingBandits {
    fn default() -> Self {
        RisingBandits { slope_window: 3, min_pulls: 4 }
    }
}

/// Best value of a best-so-far curve (INFINITY when unpulled).
fn best_val(curve: &[f64]) -> f64 {
    *curve.last().unwrap_or(&f64::INFINITY)
}

/// Improvement per pull over the trailing window (>= 0).
fn slope(curve: &[f64], window: usize) -> f64 {
    let n = curve.len();
    if n < 2 {
        return f64::INFINITY; // unknown: maximal optimism
    }
    let w = window.min(n - 1);
    ((curve[n - 1 - w] - curve[n - 1]) / w as f64).max(0.0)
}

/// Optimistic final value given `remaining` further pulls.
fn lower_bound(curve: &[f64], window: usize, remaining: usize) -> f64 {
    let s = slope(curve, window);
    if s.is_infinite() {
        return f64::NEG_INFINITY;
    }
    best_val(curve) - s * remaining as f64
}

/// One arm's trial-lifetime state, moved onto a worker thread per sweep.
struct Arm<'c, 'l> {
    state: BoState<'c>,
    shard: LedgerShard<'l>,
    rng: Rng,
    /// Best-so-far after each pull.
    curve: Vec<f64>,
    active: bool,
    /// Pulls granted for the current sweep (0 or 1).
    quota: usize,
}

impl Optimizer for RisingBandits {
    fn name(&self) -> String {
        "rb".into()
    }

    fn run(&self, ctx: &SearchContext, ledger: &mut EvalLedger, rng: &mut Rng) -> SearchResult {
        let k = ctx.domain.provider_count();
        let mut arms: Vec<Arm> = ledger
            .shard(k, 0)
            .into_iter()
            .enumerate()
            .map(|(p, shard)| Arm {
                // [22] gives no BO details; default GP-BO (EI), like our
                // CherryPick preset but with fewer init points per arm.
                state: BoState::new(
                    ctx,
                    ctx.domain.provider_grid(p),
                    BoPreset { n_init: 2, ..BoPreset::cherrypick() },
                ),
                shard,
                rng: rng.fork(p as u64),
                curve: Vec::new(),
                active: true,
                quota: 0,
            })
            .collect();

        loop {
            // Round-robin sweep: one pull per active arm, quotas fixed
            // front-to-back within the remaining budget so truncation is
            // scheduling-independent.
            let mut left = ledger.remaining();
            let mut granted = 0usize;
            for a in arms.iter_mut() {
                a.quota = usize::from(a.active && left > 0);
                left -= a.quota;
                granted += a.quota;
            }
            if granted == 0 {
                break;
            }
            arms = parallel_map_owned(arms, ctx.arm_workers, |mut a| {
                if a.quota > 0 {
                    a.shard.grant(a.quota);
                    if let Some(v) = a.state.step(&mut a.shard, &mut a.rng) {
                        let best = best_val(&a.curve).min(v);
                        a.curve.push(best);
                    }
                }
                a
            });
            for a in arms.iter_mut() {
                ledger.merge(&mut a.shard);
            }

            // Elimination pass (keep at least one arm).
            let active_count = arms.iter().filter(|a| a.active).count();
            if active_count > 1 {
                let remaining_rounds = ledger.remaining() / active_count.max(1);
                let mut to_kill: Option<usize> = None;
                for i in 0..k {
                    if !arms[i].active || arms[i].curve.len() < self.min_pulls {
                        continue;
                    }
                    let lb_i = lower_bound(&arms[i].curve, self.slope_window, remaining_rounds);
                    // Another active arm already guarantees a better value.
                    let dominated = (0..k).any(|j| {
                        j != i
                            && arms[j].active
                            && arms[j].curve.len() >= self.min_pulls
                            && best_val(&arms[j].curve) < lb_i
                    });
                    if dominated {
                        to_kill = Some(i);
                        break;
                    }
                }
                if let Some(i) = to_kill {
                    arms[i].active = false;
                }
            }
        }

        // Output: best pair of the best active arm.
        let winner = arms
            .iter()
            .filter(|a| a.active && !a.curve.is_empty())
            .min_by(|x, y| best_val(&x.curve).partial_cmp(&best_val(&y.curve)).unwrap())
            .expect("no active arm with observations");
        let (cfg, val) = winner.state.best().unwrap();
        let mut result = SearchResult::from_ledger(ledger);
        result.best_config = cfg;
        result.best_value = val;
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};
    use crate::surrogate::NativeBackend;

    #[test]
    fn slope_and_bounds() {
        let flat = [5.0, 5.0, 5.0, 5.0];
        assert_eq!(slope(&flat, 3), 0.0);
        assert_eq!(lower_bound(&flat, 3, 100), 5.0);
        let falling = [10.0, 8.0, 6.0, 4.0];
        assert!((slope(&falling, 3) - 2.0).abs() < 1e-12);
        assert!((lower_bound(&falling, 3, 2) - 0.0).abs() < 1e-12);
        let fresh = [7.0];
        assert_eq!(lower_bound(&fresh, 3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn runs_within_budget_and_returns_valid_config() {
        let ds = OfflineDataset::generate(21, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 17, Target::Cost, MeasureMode::SingleDraw, 1);
        let mut ledger = EvalLedger::new(&src, 22);
        let r = RisingBandits::default().run(&ctx, &mut ledger, &mut Rng::new(2));
        assert!(ledger.evals() <= 22);
        let _ = ds.domain.config_id(&r.best_config);
    }

    #[test]
    fn eliminates_a_hopeless_arm_eventually() {
        // Use a real dataset but check that by the end at most 2 arms keep
        // being pulled when one provider is clearly dominated.
        let ds = OfflineDataset::generate(22, 3);
        let backend = NativeBackend;
        let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend);
        let src = LookupObjective::new(&ds, 5, Target::Cost, MeasureMode::SingleDraw, 3);
        let mut ledger = EvalLedger::new(&src, 66);
        RisingBandits::default().run(&ctx, &mut ledger, &mut Rng::new(4));
        // Last 9 evaluations: how many distinct providers still pulled?
        let h = ledger.history();
        let tail = &h[h.len() - 9..];
        let mut provs: Vec<usize> = tail.iter().map(|(c, _)| c.provider).collect();
        provs.sort_unstable();
        provs.dedup();
        assert!(provs.len() <= 3); // smoke: structure holds (often < 3)
    }

    /// Parallel sweeps are bit-identical to sequential ones — the shard
    /// merge reassembles one canonical history regardless of scheduling.
    #[test]
    fn parallel_sweeps_match_sequential_bit_for_bit() {
        let ds = OfflineDataset::generate(23, 3);
        let backend = NativeBackend;
        for budget in [5usize, 22, 40] {
            for seed in [2u64, 8] {
                let run = |workers: usize| {
                    let ctx = SearchContext::new(&ds.domain, Target::Cost, &backend)
                        .with_arm_workers(workers);
                    let src =
                        LookupObjective::new(&ds, 11, Target::Cost, MeasureMode::SingleDraw, seed);
                    let mut ledger = EvalLedger::new(&src, budget);
                    let r = RisingBandits::default().run(&ctx, &mut ledger, &mut Rng::new(seed));
                    (r, ledger.history().to_vec(), ledger.total_expense())
                };
                let (r1, h1, e1) = run(1);
                let (r4, h4, e4) = run(4);
                assert_eq!(h1, h4, "B={budget} seed={seed}");
                assert_eq!(r1.best_config, r4.best_config);
                assert_eq!(r1.best_value.to_bits(), r4.best_value.to_bits());
                assert_eq!(e1.to_bits(), e4.to_bits());
            }
        }
    }
}
