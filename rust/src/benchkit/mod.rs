//! Bench harness (substrate: criterion is unavailable offline).
//!
//! Drives the `[[bench]]` targets (`harness = false`) under `cargo bench`:
//! warmup, timed iterations, mean/p50/p95 per-op latency and derived
//! throughput, printed as aligned rows and optionally dumped as CSV so the
//! §Perf before/after entries in EXPERIMENTS.md are regenerable.

use std::time::Instant;

use crate::util::stats;

pub use std::hint::black_box;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional user-supplied unit count per iteration (e.g. evaluations),
    /// for throughput = units / second.
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        self.units_per_iter / (self.mean_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A bench suite accumulating results and printing a final table.
pub struct Suite {
    title: String,
    results: Vec<BenchResult>,
    /// Max wallclock seconds to spend in the measuring loop per bench.
    pub max_seconds: f64,
    /// Min timed iterations (even if over the wallclock budget).
    pub min_iters: usize,
    pub warmup_iters: usize,
}

impl Suite {
    pub fn new(title: &str) -> Suite {
        Suite {
            title: title.to_string(),
            results: Vec::new(),
            max_seconds: 2.0,
            min_iters: 5,
            warmup_iters: 2,
        }
    }

    /// Time `f` repeatedly. `f` should perform one logical operation and
    /// return a value (passed through `black_box` to defeat DCE).
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_units(name, 1.0, &mut f)
    }

    /// Like `bench`, with `units` logical sub-operations per call for
    /// throughput reporting.
    pub fn bench_units<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        units: f64,
        f: &mut F,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let budget_ns = self.max_seconds * 1e9;
        let mut samples = Vec::new();
        let started = Instant::now();
        loop {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            let spent = started.elapsed().as_nanos() as f64;
            if samples.len() >= self.min_iters && spent > budget_ns {
                break;
            }
            if samples.len() >= 10_000 {
                break;
            }
        }
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p95_ns: stats::percentile(&samples, 95.0),
            units_per_iter: units,
        };
        eprintln!(
            "  {:<44} {:>10}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Record an externally-measured result (for end-to-end harnesses that
    /// time whole experiment grids once).
    pub fn record(&mut self, name: &str, total_ns: f64, units: f64) {
        let res = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_ns: total_ns,
            p50_ns: total_ns,
            p95_ns: total_ns,
            units_per_iter: units,
        };
        eprintln!(
            "  {:<44} {:>10} total  ({:.1} units/s)",
            res.name,
            fmt_ns(total_ns),
            res.throughput()
        );
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the summary table to stdout (captured by bench_output.txt).
    pub fn finish(&self) {
        println!("\n== {} ==", self.title);
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>14} {:>8}",
            "bench", "mean", "p50", "p95", "throughput/s", "iters"
        );
        for r in &self.results {
            println!(
                "{:<44} {:>12} {:>12} {:>12} {:>14.1} {:>8}",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p95_ns),
                r.throughput(),
                r.iters
            );
        }
    }

    /// Machine-readable JSON dump (`results/BENCH_*.json`): one object
    /// per bench with the raw latency stats and derived throughput, so
    /// the perf trajectory can be diffed across PRs by tooling instead
    /// of by eyeballing tables.
    pub fn to_json(&self) -> String {
        use crate::util::json::Value;
        let results: Vec<Value> = self
            .results
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("name", r.name.as_str().into()),
                    ("iters", r.iters.into()),
                    ("mean_ns", r.mean_ns.into()),
                    ("p50_ns", r.p50_ns.into()),
                    ("p95_ns", r.p95_ns.into()),
                    ("units_per_iter", r.units_per_iter.into()),
                    ("throughput_per_s", r.throughput().into()),
                ])
            })
            .collect();
        Value::obj(vec![
            ("title", self.title.as_str().into()),
            ("results", Value::Arr(results)),
        ])
        .to_string_pretty()
    }

    /// CSV dump for EXPERIMENTS.md §Perf bookkeeping.
    pub fn to_csv(&self) -> String {
        let mut rows = vec![vec![
            "bench".to_string(),
            "mean_ns".to_string(),
            "p50_ns".to_string(),
            "p95_ns".to_string(),
            "iters".to_string(),
            "throughput_per_s".to_string(),
        ]];
        for r in &self.results {
            rows.push(vec![
                r.name.clone(),
                format!("{:.1}", r.mean_ns),
                format!("{:.1}", r.p50_ns),
                format!("{:.1}", r.p95_ns),
                r.iters.to_string(),
                format!("{:.2}", r.throughput()),
            ]);
        }
        crate::util::csv::write_rows(&rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut s = Suite::new("t");
        s.max_seconds = 0.01;
        s.min_iters = 3;
        s.warmup_iters = 1;
        let r = s.bench("noop-ish", || (0..100).sum::<usize>());
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn throughput_uses_units() {
        let mut s = Suite::new("t");
        s.max_seconds = 0.01;
        s.min_iters = 3;
        let r = s.bench_units("u", 100.0, &mut || std::thread::sleep(std::time::Duration::from_micros(50)));
        // 100 units / ~50µs >= ~1e6/s within slack.
        assert!(r.throughput() > 1e5, "{}", r.throughput());
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut s = Suite::new("t");
        s.max_seconds = 0.005;
        s.min_iters = 2;
        s.bench("a", || 1 + 1);
        let csv = s.to_csv();
        assert!(csv.starts_with("bench,mean_ns"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn record_external() {
        let mut s = Suite::new("t");
        s.record("grid", 2e9, 100.0);
        assert_eq!(s.results().len(), 1);
        assert!((s.results()[0].throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let mut s = Suite::new("suite-title");
        s.record("gp predict", 1e6, 88.0);
        let v = crate::util::json::parse(&s.to_json()).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("suite-title"));
        let rs = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("gp predict"));
        assert_eq!(rs[0].get("mean_ns").unwrap().as_f64(), Some(1e6));
        assert!(rs[0].get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
