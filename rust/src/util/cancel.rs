//! Zero-dependency cooperative cancellation tokens.
//!
//! A [`CancelToken`] is a cheap `Arc`-cloned handle around an atomic
//! cancellation flag plus a *reason* (`disconnect`, `deadline`,
//! `shutdown`, `revoked`). Cancellation is **cooperative**: nothing is
//! interrupted;
//! workers poll [`CancelToken::is_cancelled`] at safe points (the ledger
//! checks *between pulls*) so completed work stays bit-identical.
//!
//! Tokens form a two-level hierarchy: a connection owns a root token and
//! each request derives a [`CancelToken::child`]. Firing the parent
//! (client disconnect, shutdown drain) propagates to every live child;
//! firing a child (per-request deadline) leaves the parent and sibling
//! requests untouched. Children hold only a `Weak` back-reference, so a
//! finished request's token is dropped without unbounded growth.
//!
//! Deadlines are lazy: [`CancelToken::with_deadline`] stores an
//! `Instant`; the first `is_cancelled()` call at or past the deadline
//! latches the token into the cancelled state with reason `deadline`.
//! No timer thread exists — the polling cadence (one budget pull) bounds
//! the detection latency.
//!
//! First cancel wins: once a reason is latched it never changes, even if
//! a disconnect races a deadline. The deadline *observation* is part of
//! that ordering: `is_cancelled` latches a due deadline under the same
//! lock every explicit cancel serializes on, so once a poller has seen
//! `Instant::now() >= at` the reported reason is `deadline` — a
//! disconnect or shutdown arriving in the observation window cannot
//! out-race the CAS and flap the reason.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

/// Why a token was cancelled. First cancel wins; the reason is immutable
/// once latched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// The client connection closed (EOF, hangup, or reaped).
    Disconnect,
    /// A per-request or default deadline expired.
    Deadline,
    /// The service is draining for shutdown.
    Shutdown,
    /// Spot capacity was revoked mid-trial (market/chaos harness).
    Revoked,
}

impl CancelReason {
    /// Wire-visible string, used as the `cancelled` response field.
    pub fn as_str(self) -> &'static str {
        match self {
            CancelReason::Disconnect => "disconnect",
            CancelReason::Deadline => "deadline",
            CancelReason::Shutdown => "shutdown",
            CancelReason::Revoked => "revoked",
        }
    }

    fn code(self) -> u8 {
        match self {
            CancelReason::Disconnect => 1,
            CancelReason::Deadline => 2,
            CancelReason::Shutdown => 3,
            CancelReason::Revoked => 4,
        }
    }

    fn from_code(code: u8) -> Option<CancelReason> {
        match code {
            1 => Some(CancelReason::Disconnect),
            2 => Some(CancelReason::Deadline),
            3 => Some(CancelReason::Shutdown),
            4 => Some(CancelReason::Revoked),
            _ => None,
        }
    }
}

const REASON_NONE: u8 = 0;

struct Inner {
    cancelled: AtomicBool,
    /// `REASON_NONE` until the first successful cancel CAS latches a code.
    reason: AtomicU8,
    deadline: Mutex<Option<Instant>>,
    hooks: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
    children: Mutex<Vec<Weak<Inner>>>,
    parent: Weak<Inner>,
}

impl Inner {
    fn unfired(parent: Weak<Inner>) -> Inner {
        Inner {
            cancelled: AtomicBool::new(false),
            reason: AtomicU8::new(REASON_NONE),
            deadline: Mutex::new(None),
            hooks: Mutex::new(Vec::new()),
            children: Mutex::new(Vec::new()),
            parent,
        }
    }
}

/// Latch the reason and cancelled flag. Returns `true` if this call won
/// the race (the reason was not already latched). Runs no hooks — a
/// caller that wins must follow up with [`notify`] after releasing any
/// lock it holds.
fn latch(inner: &Inner, reason: CancelReason) -> bool {
    if inner
        .reason
        .compare_exchange(REASON_NONE, reason.code(), Ordering::AcqRel, Ordering::Acquire)
        .is_err()
    {
        return false;
    }
    inner.cancelled.store(true, Ordering::Release);
    true
}

/// Winner-side follow-up to [`latch`]: run the registered hooks and
/// recursively fire live children with the same reason. Called exactly
/// once per token, by whichever caller won the latch, outside any lock.
fn notify(inner: &Arc<Inner>, reason: CancelReason) {
    let hooks = std::mem::take(&mut *inner.hooks.lock().unwrap());
    for hook in &hooks {
        hook();
    }
    let children = std::mem::take(&mut *inner.children.lock().unwrap());
    for child in children {
        if let Some(child) = child.upgrade() {
            fire(&child, reason);
        }
    }
}

/// Latch `inner` into the cancelled state with `reason`. Returns `true`
/// if this call won the race (the reason was not already latched).
/// The winner runs the registered hooks and recursively fires live
/// children with the same reason.
///
/// The latch happens under the deadline lock so an explicit cancel
/// serializes with the deadline observation in
/// [`CancelToken::is_cancelled`]: whichever side takes the lock first
/// wins, and a deadline already observed due can no longer lose its
/// reason to a disconnect racing the observer's CAS.
fn fire(inner: &Arc<Inner>, reason: CancelReason) -> bool {
    if inner.cancelled.load(Ordering::Acquire) {
        return false;
    }
    let won = {
        let _serialize = inner.deadline.lock().unwrap();
        latch(inner, reason)
    };
    if won {
        notify(inner, reason);
    }
    won
}

/// Cheap cloneable cancellation handle. See the module docs for the
/// propagation and deadline semantics.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl CancelToken {
    /// A fresh, unfired root token.
    pub fn new() -> CancelToken {
        CancelToken { inner: Arc::new(Inner::unfired(Weak::new())) }
    }

    /// Attach a lazy deadline: the first `is_cancelled()` at or past
    /// `at` latches the token with reason `Deadline`.
    pub fn with_deadline(self, at: Instant) -> CancelToken {
        *self.inner.deadline.lock().unwrap() = Some(at);
        self
    }

    /// Cancel with `reason`. Returns `true` if this call latched the
    /// token (first cancel wins), `false` if it was already cancelled.
    pub fn cancel(&self, reason: CancelReason) -> bool {
        fire(&self.inner, reason)
    }

    /// Poll for cancellation. Also latches a passed deadline and adopts
    /// a fired parent's reason, so the answer is authoritative at the
    /// moment of the call.
    pub fn is_cancelled(&self) -> bool {
        if self.inner.cancelled.load(Ordering::Acquire) {
            return true;
        }
        {
            let mut dl = self.inner.deadline.lock().unwrap();
            let due = matches!(*dl, Some(at) if Instant::now() >= at);
            if due {
                // Latch while still holding the lock: the observation
                // and the reason CAS are one atomic step with respect
                // to `fire`, so the reported reason cannot flap to a
                // disconnect/shutdown arriving in between.
                let won = latch(&self.inner, CancelReason::Deadline);
                *dl = None;
                drop(dl);
                if won {
                    notify(&self.inner, CancelReason::Deadline);
                }
                return true;
            }
        }
        // A child registered before the parent fired is reached by the
        // parent's recursive fire; this lazy check covers the window
        // where the parent latched concurrently with child registration.
        if let Some(parent) = self.inner.parent.upgrade() {
            if parent.cancelled.load(Ordering::Acquire) {
                let reason = CancelReason::from_code(parent.reason.load(Ordering::Acquire))
                    .unwrap_or(CancelReason::Disconnect);
                fire(&self.inner, reason);
                return true;
            }
        }
        false
    }

    /// The latched reason, or `None` if the token has not fired.
    pub fn reason(&self) -> Option<CancelReason> {
        CancelReason::from_code(self.inner.reason.load(Ordering::Acquire))
    }

    /// Derive a child token: firing `self` fires the child, firing the
    /// child leaves `self` untouched. If `self` already fired, the child
    /// is born cancelled with the same reason.
    pub fn child(&self) -> CancelToken {
        let child = Arc::new(Inner::unfired(Arc::downgrade(&self.inner)));
        self.inner.children.lock().unwrap().push(Arc::downgrade(&child));
        let token = CancelToken { inner: child };
        if self.inner.cancelled.load(Ordering::Acquire) {
            let reason = CancelReason::from_code(self.inner.reason.load(Ordering::Acquire))
                .unwrap_or(CancelReason::Disconnect);
            fire(&token.inner, reason);
        }
        token
    }

    /// Register a hook run exactly once when the token fires (used to
    /// wake sleeping workers). Runs immediately if already cancelled.
    pub fn on_cancel<F: Fn() + Send + Sync + 'static>(&self, hook: F) {
        if self.inner.cancelled.load(Ordering::Acquire) {
            hook();
            return;
        }
        let mut hooks = self.inner.hooks.lock().unwrap();
        // Re-check under the lock: `fire` takes the hook list while
        // holding it, so a hook pushed after the take would never run.
        if self.inner.cancelled.load(Ordering::Acquire) {
            drop(hooks);
            hook();
        } else {
            hooks.push(Box::new(hook));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn fresh_token_is_not_cancelled() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.reason(), None);
    }

    #[test]
    fn cancel_latches_flag_and_reason() {
        let t = CancelToken::new();
        assert!(t.cancel(CancelReason::Disconnect));
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some(CancelReason::Disconnect));
    }

    #[test]
    fn first_cancel_wins() {
        let t = CancelToken::new();
        assert!(t.cancel(CancelReason::Deadline));
        assert!(!t.cancel(CancelReason::Disconnect));
        assert_eq!(t.reason(), Some(CancelReason::Deadline));
    }

    #[test]
    fn clones_share_state() {
        let t = CancelToken::new();
        let c = t.clone();
        t.cancel(CancelReason::Shutdown);
        assert!(c.is_cancelled());
        assert_eq!(c.reason(), Some(CancelReason::Shutdown));
    }

    #[test]
    fn parent_fire_propagates_to_child() {
        let parent = CancelToken::new();
        let child = parent.child();
        parent.cancel(CancelReason::Disconnect);
        assert!(child.is_cancelled());
        assert_eq!(child.reason(), Some(CancelReason::Disconnect));
        assert!(parent.is_cancelled());
    }

    #[test]
    fn child_fire_leaves_parent_untouched() {
        let parent = CancelToken::new();
        let child = parent.child();
        child.cancel(CancelReason::Deadline);
        assert!(child.is_cancelled());
        assert!(!parent.is_cancelled());
        assert_eq!(parent.reason(), None);
    }

    #[test]
    fn child_of_fired_parent_is_born_cancelled() {
        let parent = CancelToken::new();
        parent.cancel(CancelReason::Shutdown);
        let child = parent.child();
        assert!(child.is_cancelled());
        assert_eq!(child.reason(), Some(CancelReason::Shutdown));
    }

    #[test]
    fn passed_deadline_latches_on_poll() {
        let t = CancelToken::new().with_deadline(Instant::now() - Duration::from_millis(1));
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some(CancelReason::Deadline));
    }

    #[test]
    fn future_deadline_does_not_fire_early() {
        let t = CancelToken::new().with_deadline(Instant::now() + Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        assert_eq!(t.reason(), None);
    }

    #[test]
    fn hooks_run_once_on_fire() {
        let hits = Arc::new(AtomicUsize::new(0));
        let t = CancelToken::new();
        let h = Arc::clone(&hits);
        t.on_cancel(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        t.cancel(CancelReason::Disconnect);
        t.cancel(CancelReason::Deadline);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn hook_on_fired_token_runs_immediately() {
        let hits = Arc::new(AtomicUsize::new(0));
        let t = CancelToken::new();
        t.cancel(CancelReason::Deadline);
        let h = Arc::clone(&hits);
        t.on_cancel(move || {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn reason_strings_match_wire_values() {
        assert_eq!(CancelReason::Disconnect.as_str(), "disconnect");
        assert_eq!(CancelReason::Deadline.as_str(), "deadline");
        assert_eq!(CancelReason::Shutdown.as_str(), "shutdown");
        assert_eq!(CancelReason::Revoked.as_str(), "revoked");
    }

    #[test]
    fn revoked_reason_latches_like_any_other() {
        let t = CancelToken::new();
        assert!(t.cancel(CancelReason::Revoked));
        assert!(t.is_cancelled());
        assert_eq!(t.reason(), Some(CancelReason::Revoked));
        assert!(!t.cancel(CancelReason::Disconnect));
        assert_eq!(t.reason(), Some(CancelReason::Revoked));
    }

    #[test]
    fn observed_deadline_beats_later_disconnect() {
        let t = CancelToken::new().with_deadline(Instant::now() - Duration::from_millis(1));
        assert!(t.is_cancelled());
        // The deadline was observed due first; a disconnect arriving
        // afterwards must lose, not flap the reported reason.
        assert!(!t.cancel(CancelReason::Disconnect));
        assert_eq!(t.reason(), Some(CancelReason::Deadline));
    }

    #[test]
    fn deadline_disconnect_race_has_exactly_one_winner() {
        // Hammer the window the latch fix closes: a due deadline being
        // polled while a disconnect fires concurrently. Exactly one
        // source wins, the winner matches the latched reason, and the
        // reason a poller observes never differs from the final one.
        for i in 0..200 {
            let t = CancelToken::new().with_deadline(Instant::now());
            let u = t.clone();
            let poller = std::thread::spawn(move || {
                while !u.is_cancelled() {
                    std::hint::spin_loop();
                }
                u.reason().expect("cancelled token must expose a latched reason")
            });
            let disconnect_won = t.cancel(CancelReason::Disconnect);
            let observed = poller.join().unwrap();
            let final_reason = t.reason().unwrap();
            assert_eq!(observed, final_reason, "iteration {i}: reason flapped");
            assert_eq!(
                disconnect_won,
                final_reason == CancelReason::Disconnect,
                "iteration {i}: winner and latched reason disagree"
            );
        }
    }
}
