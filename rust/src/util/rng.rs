//! Deterministic, fork-able pseudo-random number generation.
//!
//! The whole experiment pipeline must be reproducible from a single seed:
//! dataset generation, every optimizer's search trajectory, and the
//! coordinator's seed fan-out all draw from this module. We use
//! xoshiro256** seeded through SplitMix64 (the reference initialization),
//! which is plenty for simulation purposes and has a cheap `fork` for
//! decorrelated substreams.

/// SplitMix64 step; used for seeding and for hashing stream labels.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive a decorrelated child stream labelled by `label`.
    ///
    /// Forking is how the coordinator hands every (workload, method, seed)
    /// trial its own stream without the trials interfering, regardless of
    /// scheduling order.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize_below(0)");
        // Lemire-style rejection-free enough for simulation: modulo bias is
        // negligible for n << 2^64, but debias anyway with widening multiply.
        let x = self.next_u64() as u128;
        ((x * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize_below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative noise factor with median 1 and the given
    /// sigma of the underlying normal.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.usize_in(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: zero total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.usize_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(30, 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(idx.iter().all(|&i| i < 30));
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(17);
        let w = [0.0, 0.0, 5.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted_index(&w), 2);
        }
        let w2 = [1.0, 9.0];
        let hits = (0..10_000).filter(|_| r.weighted_index(&w2) == 1).count();
        assert!(hits > 8_500 && hits < 9_500, "hits {hits}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut r = Rng::new(23);
        let mut v: Vec<f64> = (0..9999).map(|_| r.lognormal_factor(0.3)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = v[v.len() / 2];
        assert!((med - 1.0).abs() < 0.05, "median {med}");
    }
}
