//! Deterministic fault injection for the serving path.
//!
//! A [`FaultSchedule`] scripts faults against a **logical tick** — the
//! global measurement count of the source it wraps — so a chaos run is
//! clock-free and replayable: the same schedule against the same trial
//! injects the same fault at the same pull, every time. The schedule is
//! written either with the [`at`](FaultSchedule::at) builder or in a
//! compact grammar (one spec per CI axis):
//!
//! ```text
//! "3:slow=25,7:revoke,9:panic,11:stall=50"
//!  TICK:KIND[=ARG], comma-separated, any order
//! ```
//!
//! Kinds: `slow=MS` (delayed measurement), `stall=MS` (hung source —
//! same mechanics, longer by convention), `panic` (the measurement
//! worker dies), `revoke` (capacity disappears: the schedule's
//! [`CancelToken`] fires with [`CancelReason::Revoked`], so the trial
//! winds down through ordinary cancellation — a revoked arm is a
//! cancellation, not a crash).
//!
//! [`ChaosSource`] wraps any [`EvalSource`] and applies the schedule
//! *before* delegating, never altering the measured value: everything a
//! chaotic trial completes is bit-identical to the fault-free run, which
//! is what the chaos suite asserts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::dataset::objective::EvalSource;
use crate::domain::Config;
use crate::util::cancel::{CancelReason, CancelToken};

/// One scripted fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Delay the measurement by `millis` before returning it.
    Slow { millis: u64 },
    /// A hung measurement source: same mechanics as [`Fault::Slow`],
    /// kept distinct so schedules document intent (and so stall pauses
    /// can later grow teeth without rewriting schedules).
    Stall { millis: u64 },
    /// The measurement worker panics mid-pull.
    Panic,
    /// The capacity under the trial disappears: fire the schedule's
    /// token with [`CancelReason::Revoked`]. The pull that trips the
    /// fault still completes — the ledger refuses the *next* one, so
    /// the completed prefix stays bit-identical to the unrevoked run.
    Revoke,
}

/// A fault schedule keyed by logical tick (the wrapped source's global
/// measurement index, starting at 0).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSchedule {
    events: Vec<(u64, Fault)>,
}

impl FaultSchedule {
    pub fn new() -> FaultSchedule {
        FaultSchedule::default()
    }

    /// Script `fault` at `tick` (builder-style; first entry for a tick
    /// wins at injection time).
    pub fn at(mut self, tick: u64, fault: Fault) -> FaultSchedule {
        self.events.push((tick, fault));
        self
    }

    /// The fault scheduled at `tick`, if any.
    pub fn get(&self, tick: u64) -> Option<Fault> {
        self.events.iter().find(|(t, _)| *t == tick).map(|&(_, f)| f)
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the schedule grammar: comma-separated `TICK:KIND[=ARG]`
    /// entries, e.g. `"3:slow=25,7:revoke,9:panic"`. `slow`/`stall`
    /// take an optional millisecond argument (defaults 10/50);
    /// `panic`/`revoke` take none. Empty entries are skipped, so a
    /// trailing comma is harmless.
    pub fn parse(s: &str) -> Result<FaultSchedule, String> {
        let mut sched = FaultSchedule::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (tick, kind) = part
                .split_once(':')
                .ok_or_else(|| format!("fault '{part}' must be TICK:KIND[=ARG]"))?;
            let tick: u64 = tick
                .trim()
                .parse()
                .map_err(|_| format!("bad tick in fault '{part}'"))?;
            let (kind, arg) = match kind.split_once('=') {
                Some((k, a)) => (k.trim(), Some(a.trim())),
                None => (kind.trim(), None),
            };
            let millis = |default: u64| -> Result<u64, String> {
                match arg {
                    None => Ok(default),
                    Some(a) => {
                        a.parse().map_err(|_| format!("bad millis in fault '{part}'"))
                    }
                }
            };
            let fault = match kind {
                "slow" => Fault::Slow { millis: millis(10)? },
                "stall" => Fault::Stall { millis: millis(50)? },
                "panic" | "revoke" => {
                    if arg.is_some() {
                        return Err(format!("'{kind}' takes no argument ('{part}')"));
                    }
                    if kind == "panic" {
                        Fault::Panic
                    } else {
                        Fault::Revoke
                    }
                }
                other => return Err(format!("unknown fault kind '{other}' ('{part}')")),
            };
            sched = sched.at(tick, fault);
        }
        Ok(sched)
    }
}

/// An [`EvalSource`] wrapper that injects scripted faults, driven by its
/// own atomic measurement clock. Values pass through untouched; only
/// timing, liveness, and cancellation are perturbed.
pub struct ChaosSource<'a> {
    inner: &'a dyn EvalSource,
    schedule: FaultSchedule,
    clock: AtomicU64,
    token: CancelToken,
}

impl<'a> ChaosSource<'a> {
    /// Wrap `inner`, firing `token` on [`Fault::Revoke`]. Thread the
    /// same token into the trial's ledger so a revocation cancels the
    /// work it targets.
    pub fn new(
        inner: &'a dyn EvalSource,
        schedule: FaultSchedule,
        token: CancelToken,
    ) -> ChaosSource<'a> {
        ChaosSource { inner, schedule, clock: AtomicU64::new(0), token }
    }

    /// The revocation token (clone it into a ledger via `with_cancel`).
    pub fn token(&self) -> &CancelToken {
        &self.token
    }

    /// Measurements taken so far (the next fault tick to be consulted).
    pub fn ticks(&self) -> u64 {
        self.clock.load(Ordering::Acquire)
    }
}

impl EvalSource for ChaosSource<'_> {
    fn measure(&self, cfg: &Config, pull: u64) -> f64 {
        let tick = self.clock.fetch_add(1, Ordering::AcqRel);
        match self.schedule.get(tick) {
            Some(Fault::Slow { millis }) | Some(Fault::Stall { millis }) => {
                std::thread::sleep(Duration::from_millis(millis));
            }
            Some(Fault::Panic) => panic!("chaos: injected measurement panic at tick {tick}"),
            Some(Fault::Revoke) => {
                self.token.cancel(CancelReason::Revoked);
            }
            None => {}
        }
        self.inner.measure(cfg, pull)
    }

    fn deterministic(&self) -> bool {
        self.inner.deterministic()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Flat;
    impl EvalSource for Flat {
        fn measure(&self, cfg: &Config, pull: u64) -> f64 {
            cfg.nodes as f64 + pull as f64
        }
    }

    fn cfg(nodes: usize) -> Config {
        Config { provider: 0, choices: vec![0, 0], nodes }
    }

    #[test]
    fn grammar_parses_every_kind_and_rejects_junk() {
        let s = FaultSchedule::parse("3:slow=25, 7:revoke ,9:panic,11:stall=50,").unwrap();
        assert_eq!(s.get(3), Some(Fault::Slow { millis: 25 }));
        assert_eq!(s.get(7), Some(Fault::Revoke));
        assert_eq!(s.get(9), Some(Fault::Panic));
        assert_eq!(s.get(11), Some(Fault::Stall { millis: 50 }));
        assert_eq!(s.get(4), None);
        // Argument defaults.
        let d = FaultSchedule::parse("0:slow,1:stall").unwrap();
        assert_eq!(d.get(0), Some(Fault::Slow { millis: 10 }));
        assert_eq!(d.get(1), Some(Fault::Stall { millis: 50 }));
        assert!(FaultSchedule::parse("").unwrap().is_empty());

        assert!(FaultSchedule::parse("slow=25").is_err());
        assert!(FaultSchedule::parse("x:slow").is_err());
        assert!(FaultSchedule::parse("3:melt").is_err());
        assert!(FaultSchedule::parse("3:slow=abc").is_err());
        assert!(FaultSchedule::parse("3:panic=5").is_err());
        assert!(FaultSchedule::parse("3:revoke=1").is_err());
    }

    #[test]
    fn values_pass_through_untouched_and_ticks_count_pulls() {
        let inner = Flat;
        let chaos =
            ChaosSource::new(&inner, FaultSchedule::parse("1:slow=1").unwrap(), CancelToken::new());
        for pull in 0..4u64 {
            assert_eq!(chaos.measure(&cfg(3), pull), inner.measure(&cfg(3), pull));
        }
        assert_eq!(chaos.ticks(), 4);
    }

    #[test]
    fn revoke_fires_the_token_but_completes_the_pull() {
        let inner = Flat;
        let token = CancelToken::new();
        let chaos =
            ChaosSource::new(&inner, FaultSchedule::new().at(2, Fault::Revoke), token.clone());
        assert!(!token.is_cancelled());
        assert_eq!(chaos.measure(&cfg(1), 0), inner.measure(&cfg(1), 0));
        assert_eq!(chaos.measure(&cfg(1), 1), inner.measure(&cfg(1), 1));
        assert!(!token.is_cancelled());
        // The revoking pull still returns its true value.
        assert_eq!(chaos.measure(&cfg(1), 2), inner.measure(&cfg(1), 2));
        assert!(token.is_cancelled());
        assert_eq!(token.reason(), Some(CancelReason::Revoked));
    }
}
