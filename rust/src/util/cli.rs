//! Declarative CLI argument parsing (substrate: no clap offline).
//!
//! Supports subcommands with `--flag`, `--key value` / `--key=value`
//! options and auto-generated help. The launcher (`main.rs`) builds one
//! `Command` per subcommand.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = o.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, kind, o.help));
        }
        s
    }

    /// Parse raw args (everything after the subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Args, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: BTreeMap<String, bool> = BTreeMap::new();
        for o in &self.opts {
            if o.is_flag {
                flags.insert(o.name.to_string(), false);
            } else if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            let Some(stripped) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument '{a}'\n\n{}", self.usage()));
            };
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = self
                .opts
                .iter()
                .find(|o| o.name == key)
                .ok_or_else(|| format!("unknown option '--{key}'\n\n{}", self.usage()))?;
            if spec.is_flag {
                if inline_val.is_some() {
                    return Err(format!("flag '--{key}' takes no value"));
                }
                flags.insert(key.to_string(), true);
                i += 1;
            } else {
                let v = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i).cloned().ok_or_else(|| format!("option '--{key}' needs a value"))?
                    }
                };
                values.insert(key.to_string(), v);
                i += 1;
            }
        }

        for o in &self.opts {
            if !o.is_flag && !values.contains_key(o.name) {
                return Err(format!("missing required option '--{}'\n\n{}", o.name, self.usage()));
            }
        }
        Ok(Args { values, flags })
    }
}

#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or_else(|| panic!("unknown option {name}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or_else(|| panic!("unknown flag {name}"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be an integer"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be an integer"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.get(name).parse().map_err(|_| format!("--{name} must be a number"))
    }

    /// Value of an enum-like option, validated against its allowed set.
    pub fn choice(&self, name: &str, allowed: &[&str]) -> Result<String, String> {
        let v = self.get(name);
        if allowed.contains(&v) {
            Ok(v.to_string())
        } else {
            Err(format!("--{name} must be one of: {}", allowed.join(" | ")))
        }
    }

    /// Comma-separated list.
    pub fn list(&self, name: &str) -> Vec<String> {
        let raw = self.get(name);
        if raw.is_empty() {
            Vec::new()
        } else {
            raw.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("optimize", "run one optimizer")
            .opt("budget", "33", "search budget")
            .req("method", "optimizer name")
            .flag("verbose", "chatty output")
    }

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = cmd().parse(&s(&["--method", "cloudbandit"])).unwrap();
        assert_eq!(a.get("budget"), "33");
        assert_eq!(a.usize("budget").unwrap(), 33);
        assert_eq!(a.get("method"), "cloudbandit");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cmd().parse(&s(&["--method=rs", "--budget=88", "--verbose"])).unwrap();
        assert_eq!(a.get("budget"), "88");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&s(&["--budget", "11"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&s(&["--method", "rs", "--nope", "1"])).is_err());
    }

    #[test]
    fn help_is_an_err_with_usage() {
        let e = cmd().parse(&s(&["--help"])).unwrap_err();
        assert!(e.contains("optimize"));
        assert!(e.contains("--budget"));
    }

    #[test]
    fn list_parsing() {
        let c = Command::new("x", "y").opt("methods", "a,b", "names");
        let a = c.parse(&s(&["--methods", "rs, smac ,cb"])).unwrap();
        assert_eq!(a.list("methods"), vec!["rs", "smac", "cb"]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&s(&["--method", "rs", "--verbose=1"])).is_err());
    }

    #[test]
    fn choice_validates_against_the_allowed_set() {
        let c = Command::new("serve", "x").opt("event-loop", "auto", "transport");
        let a = c.parse(&s(&[])).unwrap();
        assert_eq!(a.choice("event-loop", &["on", "off", "auto"]).unwrap(), "auto");
        let a = c.parse(&s(&["--event-loop", "off"])).unwrap();
        assert_eq!(a.choice("event-loop", &["on", "off", "auto"]).unwrap(), "off");
        let a = c.parse(&s(&["--event-loop=warp"])).unwrap();
        let e = a.choice("event-loop", &["on", "off", "auto"]).unwrap_err();
        assert!(e.contains("on | off | auto"), "{e}");
    }
}
