//! Work-queue parallelism (substrate: no tokio/rayon offline).
//!
//! Two layers:
//!
//! * [`WorkerTeam`] — a **persistent** team of worker threads fed jobs
//!   over a two-lane (normal + high-priority) queue. Queued jobs carry
//!   an optional [`JobCtl`] control block, so a caller that no longer
//!   needs a queued job can *retract* it (claim/cancel CAS arbitration)
//!   instead of waiting for a worker to pop a no-op. One team lives for
//!   the whole process
//!   ([`global_team`]); the coordinator's trial grids, the bandit
//!   optimizers' per-round arm fan-outs, and the TCP service's batch op
//!   all run on it, so a Rising-Bandits sweep (one pull per arm, dozens
//!   of sweeps per trial) pays a channel send instead of a thread
//!   spawn/join per sweep.
//! * [`parallel_map`] / [`parallel_map_owned`] — order-preserving batch
//!   helpers rebased onto the team. They pull work from a shared atomic
//!   cursor (so long items don't straggle behind a static partition) and
//!   propagate worker panics to the caller.
//!
//! Scheduling contract: the *caller always participates* in its own
//! batch, and a batch executed from a team worker thread runs inline on
//! that thread. Together these make the team deadlock-free by
//! construction — no job ever blocks on another job — and keep results
//! bit-identical to sequential execution at any team size (outputs are
//! slotted by input index, never by completion order).

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of workers to use by default: the machine's parallelism.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

thread_local! {
    /// True on threads owned by a [`WorkerTeam`]. Batches started from a
    /// team thread run inline (see module docs).
    static ON_TEAM_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a [`WorkerTeam`] worker.
pub fn on_team_thread() -> bool {
    ON_TEAM_THREAD.with(|f| f.get())
}

/// A job enqueued on the team. Lifetimes are erased at submission
/// ([`WorkerTeam::run_owned`] blocks until every job it submitted has
/// finished executing or been retracted before execution, so the borrows
/// a job captures always outlive any dereference of them).
type Job = Box<dyn FnOnce() + Send + 'static>;

const JOB_QUEUED: u8 = 0;
const JOB_CLAIMED: u8 = 1;
const JOB_CANCELLED: u8 = 2;

/// Per-job claim/cancel control block shared between the submitter and
/// the worker that eventually pops the job. Exactly one side wins the
/// CAS out of `JOB_QUEUED`:
///
/// * the worker **claims** the job right before invoking it — a claimed
///   job always runs to completion (cancellation cannot interrupt it);
/// * the submitter **cancels** a still-queued job — the worker that
///   later pops it sees `JOB_CANCELLED`, skips the call, and drops the
///   boxed closure without dereferencing any borrow it captured.
pub struct JobCtl {
    state: AtomicU8,
}

impl JobCtl {
    fn new() -> Arc<JobCtl> {
        Arc::new(JobCtl { state: AtomicU8::new(JOB_QUEUED) })
    }

    /// Worker side: `true` if this call won the job (queued → claimed).
    fn claim(&self) -> bool {
        self.state
            .compare_exchange(JOB_QUEUED, JOB_CLAIMED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Submitter side: `true` if the job was retracted before any worker
    /// claimed it (queued → cancelled). `false` means the job is already
    /// running (or finished) and will complete normally.
    pub fn cancel(&self) -> bool {
        self.state
            .compare_exchange(JOB_QUEUED, JOB_CANCELLED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Reset a claimed control block to queued so a self-resubmitting
    /// chain link can reuse it for its successor (see
    /// [`submit_batch_job`]).
    fn reopen(&self) {
        self.state.store(JOB_QUEUED, Ordering::Release);
    }
}

/// One queue entry: the closure plus its optional control block.
struct QueuedJob {
    ctl: Option<Arc<JobCtl>>,
    job: Job,
}

impl QueuedJob {
    /// Claim-CAS before deref: invoke the closure only if the job is
    /// still ours. A cancelled entry's box is dropped unopened — drop
    /// glue for the captures runs, but nothing behind an erased borrow
    /// is dereferenced.
    fn run(self) {
        let claimed = match &self.ctl {
            Some(ctl) => ctl.claim(),
            None => true,
        };
        if claimed {
            (self.job)();
        }
    }
}

/// The two job lanes plus the closed flag, all under one mutex.
struct Lanes {
    high: VecDeque<QueuedJob>,
    normal: VecDeque<QueuedJob>,
    closed: bool,
}

/// Two-condvar job queue. Normal workers (serve both lanes, high first)
/// wait on `cv_any`; priority-only workers wait on `cv_high`. A
/// high-lane push notifies one waiter on *each* condvar so the wakeup
/// can never be swallowed by a worker that is not allowed to take the
/// job; a normal push notifies `cv_any` only.
struct JobQueue {
    lanes: Mutex<Lanes>,
    cv_any: Condvar,
    cv_high: Condvar,
    priority_served: AtomicU64,
}

impl JobQueue {
    fn new() -> Arc<JobQueue> {
        Arc::new(JobQueue {
            lanes: Mutex::new(Lanes {
                high: VecDeque::new(),
                normal: VecDeque::new(),
                closed: false,
            }),
            cv_any: Condvar::new(),
            cv_high: Condvar::new(),
            priority_served: AtomicU64::new(0),
        })
    }

    /// Enqueue `entry` on the chosen lane. Returns the entry back if the
    /// queue is closed (the caller runs it inline — matches the old
    /// channel semantics where a send during shutdown fell back inline).
    fn push(&self, high: bool, entry: QueuedJob) -> Option<QueuedJob> {
        let mut lanes = self.lanes.lock().unwrap();
        if lanes.closed {
            return Some(entry);
        }
        if high {
            lanes.high.push_back(entry);
            drop(lanes);
            self.cv_high.notify_one();
            self.cv_any.notify_one();
        } else {
            lanes.normal.push_back(entry);
            drop(lanes);
            self.cv_any.notify_one();
        }
        None
    }
}

/// Worker body: pop (high lane first) and run until the queue closes.
/// Queued jobs still drain after close — exactly the old mpsc behaviour,
/// which `run_owned`'s wait-zero contract relies on.
fn worker_loop(queue: &JobQueue, priority_only: bool) {
    loop {
        let entry = {
            let mut lanes = queue.lanes.lock().unwrap();
            loop {
                if let Some(e) = lanes.high.pop_front() {
                    queue.priority_served.fetch_add(1, Ordering::Relaxed);
                    break Some(e);
                }
                if !priority_only {
                    if let Some(e) = lanes.normal.pop_front() {
                        break Some(e);
                    }
                }
                if lanes.closed {
                    break None;
                }
                lanes = if priority_only {
                    queue.cv_high.wait(lanes).unwrap()
                } else {
                    queue.cv_any.wait(lanes).unwrap()
                };
            }
        };
        match entry {
            Some(e) => e.run(),
            None => break,
        }
    }
}

/// Outstanding-job counter for one batch: the caller may not return
/// while any job it submitted could still run (jobs borrow the caller's
/// stack frame).
struct Outstanding {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Outstanding {
    fn new() -> Outstanding {
        Outstanding { count: Mutex::new(0), cv: Condvar::new() }
    }

    fn inc(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn dec(&self) {
        let mut count = self.count.lock().unwrap();
        *count -= 1;
        // Notify while still holding the lock: the waiter cannot observe
        // zero (and free this batch's stack frame) until the guard
        // drops, so this thread never touches freed memory.
        self.cv.notify_all();
    }

    fn wait_zero(&self) {
        let mut count = self.count.lock().unwrap();
        while *count > 0 {
            count = self.cv.wait(count).unwrap();
        }
    }
}

/// A persistent team of worker threads fed jobs over a two-lane queue.
///
/// * **Long-lived**: threads are spawned once and reused by every batch;
///   submitting a batch costs queue pushes, not thread spawns.
/// * **Retractable**: queued jobs carry a [`JobCtl`]; a submitter can
///   cancel a job that no worker has claimed yet.
/// * **Prioritized**: [`WorkerTeam::execute_high`] jumps the queue, and
///   teams built with [`WorkerTeam::host_pool_with_priority`] reserve
///   workers that serve *only* the high lane, so cheap control-plane
///   ops complete in bounded time even when every normal worker is
///   stuck in a long job.
/// * **Panic-propagating**: a panicking job never kills its worker
///   thread — the payload is carried back to the batch's caller and
///   resumed there, after the batch fully drains.
/// * **Drop-joins**: dropping the team closes the queue and joins every
///   worker; already-queued jobs still drain first.
pub struct WorkerTeam {
    queue: Arc<JobQueue>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    threads: usize,
    priority_threads: usize,
}

impl WorkerTeam {
    /// Spawn a team of `threads` persistent workers (0 is clamped to 1).
    pub fn new(threads: usize) -> WorkerTeam {
        WorkerTeam::spawn(threads, 0, true)
    }

    /// A team whose threads are *not* flagged as team threads: a batch
    /// started from one of its jobs fans out on the [`global_team`]
    /// normally instead of running inline. For request-hosting pools
    /// (the TCP service's connection workers) whose jobs contain nested
    /// compute fan-outs of their own — the hosting pool blocks, the
    /// compute team works, and the two sets of threads never wait on
    /// each other's queues, so the inline-nesting deadlock guard does
    /// not apply.
    pub fn host_pool(threads: usize) -> WorkerTeam {
        WorkerTeam::spawn(threads, 0, false)
    }

    /// A host pool with `priority` extra workers that serve *only* the
    /// high lane. Normal workers also prefer the high lane when both
    /// have work, so priority jobs are never slower than normal ones;
    /// the reserved workers guarantee bounded latency when every normal
    /// worker is saturated by long-running jobs.
    pub fn host_pool_with_priority(threads: usize, priority: usize) -> WorkerTeam {
        WorkerTeam::spawn(threads, priority, false)
    }

    fn spawn(threads: usize, priority_threads: usize, team_flag: bool) -> WorkerTeam {
        let threads = threads.max(1);
        let queue = JobQueue::new();
        let handles = (0..threads + priority_threads)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let priority_only = i >= threads;
                std::thread::spawn(move || {
                    ON_TEAM_THREAD.with(|f| f.set(team_flag));
                    worker_loop(&queue, priority_only);
                })
            })
            .collect();
        WorkerTeam { queue, handles: Mutex::new(handles), threads, priority_threads }
    }

    /// Submit one detached fire-and-forget job: it runs on some worker
    /// as soon as one is free, and `execute` returns immediately. This
    /// is the event-loop handoff — the loop thread deposits a parsed
    /// request and goes straight back to `poll`. A panicking job is
    /// caught and discarded (it can neither kill its worker nor
    /// propagate anywhere — detached jobs have no caller to resume on),
    /// so callers needing failure signalling must catch inside the job.
    /// During shutdown (queue closed) the job runs inline instead of
    /// being lost.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.execute_lane(false, job);
    }

    /// Like [`WorkerTeam::execute`] but on the high-priority lane: the
    /// job is popped before any queued normal job and is eligible for
    /// the reserved priority-only workers.
    pub fn execute_high(&self, job: impl FnOnce() + Send + 'static) {
        self.execute_lane(true, job);
    }

    fn execute_lane(&self, high: bool, job: impl FnOnce() + Send + 'static) {
        let job: Job = Box::new(move || {
            let _ = catch_unwind(AssertUnwindSafe(job));
        });
        self.submit_entry(high, QueuedJob { ctl: None, job });
    }

    /// Normal worker threads in the team (excludes reserved
    /// priority-only workers — batch fan-out sizing should not count
    /// workers that will never take batch jobs).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Reserved priority-only workers.
    pub fn priority_threads(&self) -> usize {
        self.priority_threads
    }

    /// Jobs served from the high-priority lane so far (by any worker).
    pub fn priority_served(&self) -> u64 {
        self.queue.priority_served.load(Ordering::Relaxed)
    }

    /// Submit one plain (uncancellable) job on the normal lane; falls
    /// back to running it inline if the team is shutting down.
    fn submit(&self, job: Job) {
        self.submit_entry(false, QueuedJob { ctl: None, job });
    }

    /// Submit one queue entry; runs it inline if the queue is closed.
    fn submit_entry(&self, high: bool, entry: QueuedJob) {
        if let Some(entry) = self.queue.push(high, entry) {
            entry.run();
        }
    }

    /// Apply `f` to every owned item on up to `workers` concurrent
    /// pullers (the caller plus team workers); results keep input order.
    ///
    /// Bit-identity: each item is processed exactly once and its result
    /// is written to the slot of its input index, so the output is
    /// independent of team size, scheduling, and completion order.
    ///
    /// Blocks until every job submitted for this batch has finished
    /// executing **or been retracted before execution**: jobs borrow the
    /// batch state on this stack frame, so returning earlier would
    /// dangle them. Once the caller drains the cursor it *cancels* its
    /// still-queued seeded jobs through their [`JobCtl`]s instead of
    /// waiting for busy workers to pop no-op chains — the only remaining
    /// wait is for jobs actually running an item. Worker panics are
    /// re-raised here after the drain.
    pub fn run_owned<T, R, F>(&self, items: Vec<T>, workers: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = workers.max(1).min(n);
        // Inline paths: sequential request, or already on a team thread
        // (a nested batch must not wait on queue slots its own ancestors
        // occupy — running inline keeps the team deadlock-free).
        if workers == 1 || on_team_thread() {
            return items.into_iter().map(f).collect();
        }

        let batch = Batch {
            cursor: AtomicUsize::new(0),
            inputs: items.into_iter().map(|t| Mutex::new(Some(t))).collect(),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            panic_slot: Mutex::new(None),
            outstanding: Outstanding::new(),
            ctls: Mutex::new(Vec::new()),
            f,
        };

        // Seed `workers - 1` one-item team jobs; the caller is the last
        // puller. Jobs are ITEM-granular and resubmit themselves while
        // work remains, so the team queue round-robins between
        // concurrent batches at item granularity — one batch's long tail
        // cannot capture a worker for another batch's whole duration.
        for _ in 0..workers - 1 {
            submit_batch_job(self, &batch);
        }
        // The caller drains the cursor alongside the team.
        while batch.run_one() {}
        // Cursor drained: retract still-queued seeded chains instead of
        // waiting for busy workers to pop no-op links. The canceller
        // that wins the CAS owns that chain's outstanding decrement
        // (each chain holds exactly one count for its whole life);
        // claimed chains are running an item and decrement themselves.
        for ctl in batch.ctls.lock().unwrap().drain(..) {
            if ctl.cancel() {
                batch.outstanding.dec();
            }
        }
        batch.outstanding.wait_zero();

        let Batch { slots, panic_slot, .. } = batch;
        if let Some(p) = panic_slot.into_inner().unwrap() {
            std::panic::resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker skipped a slot"))
            .collect()
    }
}

impl Drop for WorkerTeam {
    fn drop(&mut self) {
        // Close the queue (queued jobs still drain), then join every
        // worker.
        self.queue.lanes.lock().unwrap().closed = true;
        self.queue.cv_any.notify_all();
        self.queue.cv_high.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Shared state of one `run_owned` batch, living on the caller's stack
/// frame for the duration of the call.
struct Batch<T, R, F> {
    cursor: AtomicUsize,
    inputs: Vec<Mutex<Option<T>>>,
    slots: Vec<Mutex<Option<R>>>,
    panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    outstanding: Outstanding,
    /// Control blocks of the live seeded chains, retracted by the caller
    /// once the cursor drains.
    ctls: Mutex<Vec<Arc<JobCtl>>>,
    f: F,
}

impl<T, R, F> Batch<T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    /// Items not yet claimed by any puller.
    fn has_work(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) < self.inputs.len()
    }

    /// Claim and process one item; `false` when the cursor is drained.
    /// A panic in `f` is caught (first payload wins) so a job can never
    /// kill its worker thread; the item's slot stays empty, which is
    /// fine because the caller re-raises before collecting results.
    fn run_one(&self) -> bool {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= self.inputs.len() {
            return false;
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let item = self.inputs[i].lock().unwrap().take().expect("item taken twice");
            let out = (self.f)(item);
            *self.slots[i].lock().unwrap() = Some(out);
        }));
        if let Err(p) = r {
            let mut slot = self.panic_slot.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        true
    }
}

/// Seed one self-resubmitting one-item chain for `batch` on `team`. Each
/// chain holds exactly **one** outstanding count for its whole life: a
/// resubmitting link passes the count to its successor (no decrement),
/// and the count is released exactly once — by the terminating link (no
/// work left), or by the caller's retraction winning the cancel CAS on a
/// still-queued link.
fn submit_batch_job<T, R, F>(team: &WorkerTeam, batch: &Batch<T, R, F>)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    batch.outstanding.inc();
    let ctl = JobCtl::new();
    batch.ctls.lock().unwrap().push(Arc::clone(&ctl));
    enqueue_chain(team, batch, ctl);
}

/// Enqueue one chain link reusing `chain_ctl`. The worker claim-CASes
/// the ctl before invoking; after running an item the link *reopens* the
/// ctl and re-enqueues itself while unclaimed items remain. Reopening is
/// race-free with retraction: the caller only retracts after draining
/// the cursor, at which point `has_work()` is false and no link
/// resubmits — so a reopened ctl is always observed by either its
/// successor's worker (claim) or the canceller (cancel), never both.
fn enqueue_chain<T, R, F>(team: &WorkerTeam, batch: &Batch<T, R, F>, chain_ctl: Arc<JobCtl>)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let ctl = Arc::clone(&chain_ctl);
    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
        batch.run_one();
        if batch.has_work() {
            chain_ctl.reopen();
            enqueue_chain(team, batch, chain_ctl);
            // The successor inherits this chain's outstanding count.
            return;
        }
        batch.outstanding.dec();
    });
    // SAFETY: `run_owned` blocks on `outstanding.wait_zero()` until
    // every chain seeded for its batch has either fully finished
    // executing or been retracted by the caller (which then performs the
    // chain's single decrement itself), so the borrows the job captures
    // — `batch` on the caller's stack and `team` behind the caller's
    // `&self` — strictly outlive any dereference: a worker claim-CASes
    // the ctl before invoking, and a cancelled link's box is dropped
    // without being called (its captures are a `&Batch`, a `&WorkerTeam`
    // and an `Arc<JobCtl>`; dropping them dereferences nothing borrowed,
    // and the Arc keeps the ctl alive independently). Dropping that box
    // may happen after `run_owned` returned, which is exactly why the
    // drop must not — and does not — touch the erased borrows. The
    // transmute only erases the lifetime bound of the trait object; the
    // layout is identical.
    let job: Job = unsafe { std::mem::transmute(job) };
    team.submit_entry(false, QueuedJob { ctl: Some(ctl), job });
}

/// The process-wide team every batch helper runs on, sized to the
/// machine's parallelism and spawned on first use. The TCP service's
/// scheduler shares this same team, so one process owns exactly one set
/// of compute threads regardless of how many requests are in flight.
pub fn global_team() -> &'static WorkerTeam {
    static TEAM: OnceLock<WorkerTeam> = OnceLock::new();
    TEAM.get_or_init(|| WorkerTeam::new(default_workers()))
}

/// Apply `f` to every item on up to `workers` concurrent pullers of the
/// process [`global_team`]; results keep input order. `f` must be `Sync`
/// (it is shared, not cloned). Panics in workers are re-raised on the
/// caller thread after the batch drains.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let refs: Vec<&T> = items.iter().collect();
    parallel_map_owned(refs, workers, |t| f(t))
}

/// Like [`parallel_map`] but each item is moved into `f` and the
/// (possibly transformed) results come back in input order. This is the
/// substrate for parallel arm execution inside one bandit trial: each
/// arm task owns mutable state (component-optimizer state, ledger shard,
/// RNG) that a shared-reference `parallel_map` closure could not touch.
/// Runs on the persistent [`global_team`] — no per-call thread spawns.
pub fn parallel_map_owned<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    global_team().run_owned(items, workers, f)
}

/// Reference implementation of [`parallel_map_owned`] that spawns scoped
/// threads per call (the pre-team behaviour). Kept for the
/// `perf_service` bench, which quantifies exactly the spawn/join
/// overhead the persistent team amortizes, and for differential tests.
pub fn parallel_map_owned_spawn<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f_ref = &f;
    let cursor_ref = &cursor;
    let inputs_ref = &inputs;
    let slots_ref = &slots;

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || loop {
                    let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = inputs_ref[i].lock().unwrap().take().expect("item taken twice");
                    let r = f_ref(item);
                    *slots_ref[i].lock().unwrap() = Some(r);
                })
            })
            .collect();
        for h in handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker skipped a slot"))
        .collect()
}

/// Like [`parallel_map`] but with a progress callback invoked (from
/// worker threads) after each completed item with the number done so far.
pub fn parallel_map_progress<T, R, F, P>(items: Vec<T>, workers: usize, f: F, progress: P) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let done = AtomicUsize::new(0);
    let n = items.len();
    let done_ref = &done;
    let progress_ref = &progress;
    let f_ref = &f;
    parallel_map(items, workers, move |t| {
        let r = f_ref(t);
        let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
        progress_ref(d, n);
        r
    })
}

/// Like [`parallel_map_progress`] but on dedicated scoped threads
/// (spawn-per-batch) instead of the team. Use when each item runs a
/// nested team batch of its own — e.g. grid trials with arm workers > 1:
/// a team-executed item runs its nested batch inline (see module docs),
/// so the nested level would never actually parallelize. Dedicated
/// threads at the outer level keep both levels genuinely concurrent; the
/// spawn cost is paid once per batch, amortized over all items.
pub fn parallel_map_progress_spawn<T, R, F, P>(
    items: Vec<T>,
    workers: usize,
    f: F,
    progress: P,
) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let done = AtomicUsize::new(0);
    let n = items.len();
    let done_ref = &done;
    let progress_ref = &progress;
    let f_ref = &f;
    let refs: Vec<&T> = items.iter().collect();
    parallel_map_owned_spawn(refs, workers, move |t| {
        let r = f_ref(t);
        let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
        progress_ref(d, n);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(Vec::<usize>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5, 6], 16, |&x| x);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let counter = AtomicU64::new(0);
        let _ = parallel_map((0..500).collect::<Vec<_>>(), 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let _ = parallel_map(vec![0usize, 1, 2], 2, |&x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn team_survives_a_panicking_batch() {
        // A panic is propagated to the batch caller but must not kill the
        // team's threads: the next batch on the same team still works.
        let team = WorkerTeam::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            team.run_owned((0..16).collect::<Vec<usize>>(), 3, |x| {
                if x == 7 {
                    panic!("one bad item");
                }
                x
            })
        }));
        assert!(r.is_err());
        let out = team.run_owned(vec![1usize, 2, 3], 3, |x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn owned_map_preserves_order_and_moves_state() {
        // Each item carries mutable state the closure consumes and
        // returns transformed.
        let items: Vec<Vec<usize>> = (0..200).map(|i| vec![i]).collect();
        let out = parallel_map_owned(items, 8, |mut v| {
            v.push(v[0] * 2);
            v
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, [i, i * 2]);
        }
    }

    #[test]
    fn owned_map_single_worker_and_empty() {
        let out = parallel_map_owned(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<usize> = parallel_map_owned(Vec::<usize>::new(), 4, |x| x);
        assert!(empty.is_empty());
    }

    #[test]
    #[should_panic(expected = "owned boom")]
    fn owned_map_panic_propagates() {
        let _ = parallel_map_owned(vec![0usize, 1, 2], 2, |x| {
            if x == 1 {
                panic!("owned boom");
            }
            x
        });
    }

    #[test]
    fn team_and_spawn_paths_agree() {
        let items: Vec<usize> = (0..300).collect();
        let a = parallel_map_owned(items.clone(), 6, |x| x * x + 1);
        let b = parallel_map_owned_spawn(items, 6, |x| x * x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn nested_batches_run_inline_without_deadlock() {
        // Outer batch on the team; each item starts an inner batch. Inner
        // batches run inline on their team thread (on_team_thread), so
        // this terminates even when outer items outnumber team threads.
        let team = WorkerTeam::new(2);
        let out = team.run_owned((0..8).collect::<Vec<usize>>(), 8, |x| {
            let inner = parallel_map_owned((0..5).collect::<Vec<usize>>(), 4, |y| y + x);
            inner.iter().sum::<usize>()
        });
        for (x, s) in out.iter().enumerate() {
            assert_eq!(*s, 10 + 5 * x);
        }
    }

    #[test]
    fn concurrent_batches_share_one_team() {
        // Many caller threads hammer the global team at once; every batch
        // gets its own correct, ordered results.
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..6)
                .map(|t| {
                    scope.spawn(move || {
                        let items: Vec<usize> = (0..120).collect();
                        let out = parallel_map_owned(items, 4, move |x| x * 3 + t);
                        for (i, v) in out.iter().enumerate() {
                            assert_eq!(*v, i * 3 + t);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn execute_runs_detached_jobs() {
        let pool = WorkerTeam::host_pool(2);
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        for i in 0..10 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i).unwrap());
        }
        let mut got: Vec<usize> = (0..10)
            .map(|_| rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap())
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn execute_survives_a_panicking_job() {
        let pool = WorkerTeam::host_pool(1);
        let (tx, rx) = std::sync::mpsc::channel::<&'static str>();
        pool.execute(|| panic!("detached boom"));
        let tx2 = tx.clone();
        pool.execute(move || tx2.send("alive").unwrap());
        // The single worker must survive the panic and run the next job.
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), "alive");
    }

    /// Host-pool threads are not team threads: a nested batch started
    /// from a hosted job fans out on the global team (and completes)
    /// instead of tripping the run-inline rule.
    #[test]
    fn host_pool_jobs_fan_nested_batches_onto_the_global_team() {
        let pool = WorkerTeam::host_pool(2);
        let (tx, rx) = std::sync::mpsc::channel::<(bool, Vec<usize>)>();
        pool.execute(move || {
            let flagged = on_team_thread();
            let out = parallel_map_owned((0..50).collect::<Vec<usize>>(), 4, |x| x * 2);
            tx.send((flagged, out)).unwrap();
        });
        let (flagged, out) = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert!(!flagged, "host-pool threads must not be flagged as team threads");
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let team = WorkerTeam::new(4);
        assert_eq!(team.threads(), 4);
        let out = team.run_owned(vec![1usize, 2, 3, 4], 4, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
        drop(team); // must not hang or leak threads
    }

    #[test]
    fn progress_spawn_variant_matches_team_variant() {
        let items: Vec<usize> = (0..200).collect();
        let calls = AtomicUsize::new(0);
        let a = parallel_map_progress_spawn(
            items.clone(),
            4,
            |&x| x * 7,
            |done, total| {
                assert!(done <= total);
                calls.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(calls.load(Ordering::Relaxed), 200);
        let b = parallel_map_progress(items, 4, |&x| x * 7, |_, _| {});
        assert_eq!(a, b);
    }

    #[test]
    fn job_ctl_claim_and_cancel_arbitrate() {
        let ctl = JobCtl::new();
        assert!(ctl.claim());
        assert!(!ctl.cancel(), "claimed job cannot be cancelled");
        ctl.reopen();
        assert!(ctl.cancel());
        assert!(!ctl.claim(), "cancelled job cannot be claimed");
    }

    #[test]
    fn execute_high_jumps_the_queue() {
        // One normal worker, blocked on a gate; queue a normal job and a
        // high job while it is busy. The high job must be popped first.
        let pool = WorkerTeam::host_pool(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        let (order_tx, order_rx) = std::sync::mpsc::channel::<&'static str>();
        pool.execute(move || {
            gate_rx.recv().unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        let t1 = order_tx.clone();
        pool.execute(move || t1.send("normal").unwrap());
        let t2 = order_tx.clone();
        pool.execute_high(move || t2.send("high").unwrap());
        gate_tx.send(()).unwrap();
        let first = order_rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(first, "high");
        assert!(pool.priority_served() >= 1);
    }

    #[test]
    fn priority_worker_answers_while_normal_lane_is_saturated() {
        // Every normal worker is parked in a long job; a reserved
        // priority-only worker must still serve the high lane.
        let pool = WorkerTeam::host_pool_with_priority(2, 1);
        assert_eq!(pool.threads(), 2);
        assert_eq!(pool.priority_threads(), 1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        let gate_rx = Arc::new(Mutex::new(gate_rx));
        for _ in 0..2 {
            let gate_rx = Arc::clone(&gate_rx);
            pool.execute(move || {
                gate_rx.lock().unwrap().recv().unwrap();
            });
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
        let (tx, rx) = std::sync::mpsc::channel::<&'static str>();
        pool.execute_high(move || tx.send("served").unwrap());
        // Bounded wait well under the gate release: the priority worker
        // is idle and must pick the job up promptly.
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), "served");
        assert!(pool.priority_served() >= 1);
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
    }

    #[test]
    fn drained_caller_retracts_queued_seed_jobs() {
        // One team worker, parked in a detached job. run_owned seeds one
        // chain job that will never be popped while the worker is busy;
        // the caller drains every item itself and must RETRACT the
        // queued seed instead of waiting for the busy worker — before
        // this PR, run_owned would block here until the gate released.
        let team = WorkerTeam::new(1);
        let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
        team.execute(move || {
            gate_rx.recv().unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        let start = std::time::Instant::now();
        let out = team.run_owned(vec![1usize, 2, 3], 2, |x| x * 2);
        assert_eq!(out, vec![2, 4, 6]);
        assert!(
            start.elapsed() < std::time::Duration::from_secs(5),
            "caller should not wait for the busy worker to pop its no-op seed"
        );
        // Only now release the parked worker.
        gate_tx.send(()).unwrap();
    }

    #[test]
    fn retraction_under_concurrent_batches_loses_no_items() {
        // Hammer the retraction path: many batches race on a tiny team,
        // so seeded chains are frequently retracted after the caller
        // drains. Every item must still be processed exactly once.
        let team = Arc::new(WorkerTeam::new(2));
        let counter = Arc::new(AtomicU64::new(0));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let team = Arc::clone(&team);
                let counter = Arc::clone(&counter);
                scope.spawn(move || {
                    for _ in 0..50 {
                        let c = Arc::clone(&counter);
                        let out = team.run_owned((0..10).collect::<Vec<usize>>(), 3, move |x| {
                            c.fetch_add(1, Ordering::Relaxed);
                            x
                        });
                        assert_eq!(out, (0..10).collect::<Vec<_>>());
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 8 * 50 * 10);
    }

    #[test]
    fn progress_reaches_total() {
        let max_seen = AtomicUsize::new(0);
        let _ = parallel_map_progress(
            (0..100).collect::<Vec<_>>(),
            4,
            |&x| x,
            |done, total| {
                assert!(done <= total);
                max_seen.fetch_max(done, Ordering::Relaxed);
            },
        );
        assert_eq!(max_seen.load(Ordering::Relaxed), 100);
    }
}
