//! Work-queue parallelism (substrate: no tokio/rayon offline).
//!
//! Two layers:
//!
//! * [`WorkerTeam`] — a **persistent** team of worker threads fed jobs
//!   over a channel. One team lives for the whole process
//!   ([`global_team`]); the coordinator's trial grids, the bandit
//!   optimizers' per-round arm fan-outs, and the TCP service's batch op
//!   all run on it, so a Rising-Bandits sweep (one pull per arm, dozens
//!   of sweeps per trial) pays a channel send instead of a thread
//!   spawn/join per sweep.
//! * [`parallel_map`] / [`parallel_map_owned`] — order-preserving batch
//!   helpers rebased onto the team. They pull work from a shared atomic
//!   cursor (so long items don't straggle behind a static partition) and
//!   propagate worker panics to the caller.
//!
//! Scheduling contract: the *caller always participates* in its own
//! batch, and a batch executed from a team worker thread runs inline on
//! that thread. Together these make the team deadlock-free by
//! construction — no job ever blocks on another job — and keep results
//! bit-identical to sequential execution at any team size (outputs are
//! slotted by input index, never by completion order).

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of workers to use by default: the machine's parallelism.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

thread_local! {
    /// True on threads owned by a [`WorkerTeam`]. Batches started from a
    /// team thread run inline (see module docs).
    static ON_TEAM_THREAD: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a [`WorkerTeam`] worker.
pub fn on_team_thread() -> bool {
    ON_TEAM_THREAD.with(|f| f.get())
}

/// A job enqueued on the team. Lifetimes are erased at submission
/// ([`WorkerTeam::run_owned`] blocks until every job it submitted has
/// finished executing, so the borrows a job captures always outlive it).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Outstanding-job counter for one batch: the caller may not return
/// while any job it submitted could still run (jobs borrow the caller's
/// stack frame).
struct Outstanding {
    count: Mutex<usize>,
    cv: Condvar,
}

impl Outstanding {
    fn new() -> Outstanding {
        Outstanding { count: Mutex::new(0), cv: Condvar::new() }
    }

    fn inc(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn dec(&self) {
        let mut count = self.count.lock().unwrap();
        *count -= 1;
        // Notify while still holding the lock: the waiter cannot observe
        // zero (and free this batch's stack frame) until the guard
        // drops, so this thread never touches freed memory.
        self.cv.notify_all();
    }

    fn wait_zero(&self) {
        let mut count = self.count.lock().unwrap();
        while *count > 0 {
            count = self.cv.wait(count).unwrap();
        }
    }
}

/// A persistent team of worker threads fed jobs over a channel.
///
/// * **Long-lived**: threads are spawned once and reused by every batch;
///   submitting a batch costs channel sends, not thread spawns.
/// * **Panic-propagating**: a panicking job never kills its worker
///   thread — the payload is carried back to the batch's caller and
///   resumed there, after the batch fully drains.
/// * **Drop-joins**: dropping the team closes the job channel and joins
///   every worker.
pub struct WorkerTeam {
    tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    threads: usize,
}

impl WorkerTeam {
    /// Spawn a team of `threads` persistent workers (0 is clamped to 1).
    pub fn new(threads: usize) -> WorkerTeam {
        WorkerTeam::spawn(threads, true)
    }

    /// A team whose threads are *not* flagged as team threads: a batch
    /// started from one of its jobs fans out on the [`global_team`]
    /// normally instead of running inline. For request-hosting pools
    /// (the TCP service's connection workers) whose jobs contain nested
    /// compute fan-outs of their own — the hosting pool blocks, the
    /// compute team works, and the two sets of threads never wait on
    /// each other's queues, so the inline-nesting deadlock guard does
    /// not apply.
    pub fn host_pool(threads: usize) -> WorkerTeam {
        WorkerTeam::spawn(threads, false)
    }

    fn spawn(threads: usize, team_flag: bool) -> WorkerTeam {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || {
                    ON_TEAM_THREAD.with(|f| f.set(team_flag));
                    loop {
                        // The receiver guard is a temporary: held while
                        // popping, released before the job runs.
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: team dropped
                        }
                    }
                })
            })
            .collect();
        WorkerTeam { tx: Mutex::new(Some(tx)), handles: Mutex::new(handles), threads }
    }

    /// Submit one detached fire-and-forget job: it runs on some worker
    /// as soon as one is free, and `execute` returns immediately. This
    /// is the event-loop handoff — the loop thread deposits a parsed
    /// request and goes straight back to `poll`. A panicking job is
    /// caught and discarded (it can neither kill its worker nor
    /// propagate anywhere — detached jobs have no caller to resume on),
    /// so callers needing failure signalling must catch inside the job.
    /// During shutdown (channel closed) the job runs inline instead of
    /// being lost.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.submit(Box::new(move || {
            let _ = catch_unwind(AssertUnwindSafe(job));
        }));
    }

    /// Worker threads in the team.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit one job; falls back to running it inline if the team is
    /// shutting down (the channel is closed).
    fn submit(&self, job: Job) {
        let failed = {
            let guard = self.tx.lock().unwrap();
            match guard.as_ref() {
                Some(tx) => tx.send(job).err().map(|e| e.0),
                None => Some(job),
            }
        };
        if let Some(job) = failed {
            job();
        }
    }

    /// Apply `f` to every owned item on up to `workers` concurrent
    /// pullers (the caller plus team workers); results keep input order.
    ///
    /// Bit-identity: each item is processed exactly once and its result
    /// is written to the slot of its input index, so the output is
    /// independent of team size, scheduling, and completion order.
    ///
    /// Blocks until every job submitted for this batch has finished
    /// executing (not merely until all items are done): jobs borrow the
    /// batch state on this stack frame, so returning earlier would
    /// dangle them — a queued job cannot be cancelled, only awaited.
    /// Consequently, when every team worker is busy with another batch's
    /// long items, a caller that drained its own cursor still waits for
    /// its (by then no-op) seeded jobs to be popped — bounded by the
    /// in-flight items' remaining runtime, since all jobs are one item
    /// long. Worker panics are re-raised here after the drain.
    pub fn run_owned<T, R, F>(&self, items: Vec<T>, workers: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = workers.max(1).min(n);
        // Inline paths: sequential request, or already on a team thread
        // (a nested batch must not wait on queue slots its own ancestors
        // occupy — running inline keeps the team deadlock-free).
        if workers == 1 || on_team_thread() {
            return items.into_iter().map(f).collect();
        }

        let batch = Batch {
            cursor: AtomicUsize::new(0),
            inputs: items.into_iter().map(|t| Mutex::new(Some(t))).collect(),
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
            panic_slot: Mutex::new(None),
            outstanding: Outstanding::new(),
            f,
        };

        // Seed `workers - 1` one-item team jobs; the caller is the last
        // puller. Jobs are ITEM-granular and resubmit themselves while
        // work remains, so the team queue round-robins between
        // concurrent batches at item granularity — one batch's long tail
        // cannot capture a worker for another batch's whole duration.
        for _ in 0..workers - 1 {
            submit_batch_job(self, &batch);
        }
        // The caller drains the cursor alongside the team.
        while batch.run_one() {}
        batch.outstanding.wait_zero();

        let Batch { slots, panic_slot, .. } = batch;
        if let Some(p) = panic_slot.into_inner().unwrap() {
            std::panic::resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker skipped a slot"))
            .collect()
    }
}

impl Drop for WorkerTeam {
    fn drop(&mut self) {
        // Close the channel, then join every worker.
        *self.tx.lock().unwrap() = None;
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Shared state of one `run_owned` batch, living on the caller's stack
/// frame for the duration of the call.
struct Batch<T, R, F> {
    cursor: AtomicUsize,
    inputs: Vec<Mutex<Option<T>>>,
    slots: Vec<Mutex<Option<R>>>,
    panic_slot: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    outstanding: Outstanding,
    f: F,
}

impl<T, R, F> Batch<T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    /// Items not yet claimed by any puller.
    fn has_work(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) < self.inputs.len()
    }

    /// Claim and process one item; `false` when the cursor is drained.
    /// A panic in `f` is caught (first payload wins) so a job can never
    /// kill its worker thread; the item's slot stays empty, which is
    /// fine because the caller re-raises before collecting results.
    fn run_one(&self) -> bool {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= self.inputs.len() {
            return false;
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            let item = self.inputs[i].lock().unwrap().take().expect("item taken twice");
            let out = (self.f)(item);
            *self.slots[i].lock().unwrap() = Some(out);
        }));
        if let Err(p) = r {
            let mut slot = self.panic_slot.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        true
    }
}

/// Enqueue one one-item job for `batch` on `team`. The job processes a
/// single item, resubmits itself while unclaimed items remain, and only
/// then marks itself no longer outstanding (resubmit-before-decrement,
/// so the caller's zero-wait can never fire while a successor is in
/// flight).
fn submit_batch_job<T, R, F>(team: &WorkerTeam, batch: &Batch<T, R, F>)
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    batch.outstanding.inc();
    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
        batch.run_one();
        if batch.has_work() {
            submit_batch_job(team, batch);
        }
        batch.outstanding.dec();
    });
    // SAFETY: `run_owned` blocks on `outstanding.wait_zero()` until
    // every job submitted for its batch has fully finished executing
    // (the resubmit-before-decrement order makes the count conservative),
    // so the borrows the job captures — `batch` on the caller's stack
    // and `team` behind the caller's `&self` — strictly outlive its
    // execution. The transmute only erases the lifetime bound of the
    // trait object; the layout is identical.
    let job: Job = unsafe { std::mem::transmute(job) };
    team.submit(job);
}

/// The process-wide team every batch helper runs on, sized to the
/// machine's parallelism and spawned on first use. The TCP service's
/// scheduler shares this same team, so one process owns exactly one set
/// of compute threads regardless of how many requests are in flight.
pub fn global_team() -> &'static WorkerTeam {
    static TEAM: OnceLock<WorkerTeam> = OnceLock::new();
    TEAM.get_or_init(|| WorkerTeam::new(default_workers()))
}

/// Apply `f` to every item on up to `workers` concurrent pullers of the
/// process [`global_team`]; results keep input order. `f` must be `Sync`
/// (it is shared, not cloned). Panics in workers are re-raised on the
/// caller thread after the batch drains.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let refs: Vec<&T> = items.iter().collect();
    parallel_map_owned(refs, workers, |t| f(t))
}

/// Like [`parallel_map`] but each item is moved into `f` and the
/// (possibly transformed) results come back in input order. This is the
/// substrate for parallel arm execution inside one bandit trial: each
/// arm task owns mutable state (component-optimizer state, ledger shard,
/// RNG) that a shared-reference `parallel_map` closure could not touch.
/// Runs on the persistent [`global_team`] — no per-call thread spawns.
pub fn parallel_map_owned<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    global_team().run_owned(items, workers, f)
}

/// Reference implementation of [`parallel_map_owned`] that spawns scoped
/// threads per call (the pre-team behaviour). Kept for the
/// `perf_service` bench, which quantifies exactly the spawn/join
/// overhead the persistent team amortizes, and for differential tests.
pub fn parallel_map_owned_spawn<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f_ref = &f;
    let cursor_ref = &cursor;
    let inputs_ref = &inputs;
    let slots_ref = &slots;

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || loop {
                    let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = inputs_ref[i].lock().unwrap().take().expect("item taken twice");
                    let r = f_ref(item);
                    *slots_ref[i].lock().unwrap() = Some(r);
                })
            })
            .collect();
        for h in handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker skipped a slot"))
        .collect()
}

/// Like [`parallel_map`] but with a progress callback invoked (from
/// worker threads) after each completed item with the number done so far.
pub fn parallel_map_progress<T, R, F, P>(items: Vec<T>, workers: usize, f: F, progress: P) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let done = AtomicUsize::new(0);
    let n = items.len();
    let done_ref = &done;
    let progress_ref = &progress;
    let f_ref = &f;
    parallel_map(items, workers, move |t| {
        let r = f_ref(t);
        let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
        progress_ref(d, n);
        r
    })
}

/// Like [`parallel_map_progress`] but on dedicated scoped threads
/// (spawn-per-batch) instead of the team. Use when each item runs a
/// nested team batch of its own — e.g. grid trials with arm workers > 1:
/// a team-executed item runs its nested batch inline (see module docs),
/// so the nested level would never actually parallelize. Dedicated
/// threads at the outer level keep both levels genuinely concurrent; the
/// spawn cost is paid once per batch, amortized over all items.
pub fn parallel_map_progress_spawn<T, R, F, P>(
    items: Vec<T>,
    workers: usize,
    f: F,
    progress: P,
) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let done = AtomicUsize::new(0);
    let n = items.len();
    let done_ref = &done;
    let progress_ref = &progress;
    let f_ref = &f;
    let refs: Vec<&T> = items.iter().collect();
    parallel_map_owned_spawn(refs, workers, move |t| {
        let r = f_ref(t);
        let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
        progress_ref(d, n);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(Vec::<usize>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5, 6], 16, |&x| x);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let counter = AtomicU64::new(0);
        let _ = parallel_map((0..500).collect::<Vec<_>>(), 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let _ = parallel_map(vec![0usize, 1, 2], 2, |&x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn team_survives_a_panicking_batch() {
        // A panic is propagated to the batch caller but must not kill the
        // team's threads: the next batch on the same team still works.
        let team = WorkerTeam::new(3);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            team.run_owned((0..16).collect::<Vec<usize>>(), 3, |x| {
                if x == 7 {
                    panic!("one bad item");
                }
                x
            })
        }));
        assert!(r.is_err());
        let out = team.run_owned(vec![1usize, 2, 3], 3, |x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn owned_map_preserves_order_and_moves_state() {
        // Each item carries mutable state the closure consumes and
        // returns transformed.
        let items: Vec<Vec<usize>> = (0..200).map(|i| vec![i]).collect();
        let out = parallel_map_owned(items, 8, |mut v| {
            v.push(v[0] * 2);
            v
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, [i, i * 2]);
        }
    }

    #[test]
    fn owned_map_single_worker_and_empty() {
        let out = parallel_map_owned(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<usize> = parallel_map_owned(Vec::<usize>::new(), 4, |x| x);
        assert!(empty.is_empty());
    }

    #[test]
    #[should_panic(expected = "owned boom")]
    fn owned_map_panic_propagates() {
        let _ = parallel_map_owned(vec![0usize, 1, 2], 2, |x| {
            if x == 1 {
                panic!("owned boom");
            }
            x
        });
    }

    #[test]
    fn team_and_spawn_paths_agree() {
        let items: Vec<usize> = (0..300).collect();
        let a = parallel_map_owned(items.clone(), 6, |x| x * x + 1);
        let b = parallel_map_owned_spawn(items, 6, |x| x * x + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn nested_batches_run_inline_without_deadlock() {
        // Outer batch on the team; each item starts an inner batch. Inner
        // batches run inline on their team thread (on_team_thread), so
        // this terminates even when outer items outnumber team threads.
        let team = WorkerTeam::new(2);
        let out = team.run_owned((0..8).collect::<Vec<usize>>(), 8, |x| {
            let inner = parallel_map_owned((0..5).collect::<Vec<usize>>(), 4, |y| y + x);
            inner.iter().sum::<usize>()
        });
        for (x, s) in out.iter().enumerate() {
            assert_eq!(*s, 10 + 5 * x);
        }
    }

    #[test]
    fn concurrent_batches_share_one_team() {
        // Many caller threads hammer the global team at once; every batch
        // gets its own correct, ordered results.
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..6)
                .map(|t| {
                    scope.spawn(move || {
                        let items: Vec<usize> = (0..120).collect();
                        let out = parallel_map_owned(items, 4, move |x| x * 3 + t);
                        for (i, v) in out.iter().enumerate() {
                            assert_eq!(*v, i * 3 + t);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn execute_runs_detached_jobs() {
        let pool = WorkerTeam::host_pool(2);
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        for i in 0..10 {
            let tx = tx.clone();
            pool.execute(move || tx.send(i).unwrap());
        }
        let mut got: Vec<usize> = (0..10)
            .map(|_| rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap())
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn execute_survives_a_panicking_job() {
        let pool = WorkerTeam::host_pool(1);
        let (tx, rx) = std::sync::mpsc::channel::<&'static str>();
        pool.execute(|| panic!("detached boom"));
        let tx2 = tx.clone();
        pool.execute(move || tx2.send("alive").unwrap());
        // The single worker must survive the panic and run the next job.
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), "alive");
    }

    /// Host-pool threads are not team threads: a nested batch started
    /// from a hosted job fans out on the global team (and completes)
    /// instead of tripping the run-inline rule.
    #[test]
    fn host_pool_jobs_fan_nested_batches_onto_the_global_team() {
        let pool = WorkerTeam::host_pool(2);
        let (tx, rx) = std::sync::mpsc::channel::<(bool, Vec<usize>)>();
        pool.execute(move || {
            let flagged = on_team_thread();
            let out = parallel_map_owned((0..50).collect::<Vec<usize>>(), 4, |x| x * 2);
            tx.send((flagged, out)).unwrap();
        });
        let (flagged, out) = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert!(!flagged, "host-pool threads must not be flagged as team threads");
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let team = WorkerTeam::new(4);
        assert_eq!(team.threads(), 4);
        let out = team.run_owned(vec![1usize, 2, 3, 4], 4, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4, 5]);
        drop(team); // must not hang or leak threads
    }

    #[test]
    fn progress_spawn_variant_matches_team_variant() {
        let items: Vec<usize> = (0..200).collect();
        let calls = AtomicUsize::new(0);
        let a = parallel_map_progress_spawn(
            items.clone(),
            4,
            |&x| x * 7,
            |done, total| {
                assert!(done <= total);
                calls.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(calls.load(Ordering::Relaxed), 200);
        let b = parallel_map_progress(items, 4, |&x| x * 7, |_, _| {});
        assert_eq!(a, b);
    }

    #[test]
    fn progress_reaches_total() {
        let max_seen = AtomicUsize::new(0);
        let _ = parallel_map_progress(
            (0..100).collect::<Vec<_>>(),
            4,
            |&x| x,
            |done, total| {
                assert!(done <= total);
                max_seen.fetch_max(done, Ordering::Relaxed);
            },
        );
        assert_eq!(max_seen.load(Ordering::Relaxed), 100);
    }
}
