//! Work-queue parallelism (substrate: no tokio/rayon offline).
//!
//! The coordinator fans thousands of independent trials (workload x method
//! x budget x seed) across cores. `parallel_map` preserves input order in
//! the output, pulls work from a shared atomic cursor (so long trials don't
//! straggle behind a static partition), and propagates panics.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default: the machine's parallelism.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item on `workers` threads; results keep input order.
///
/// `f` must be `Sync` (it is shared, not cloned). Panics in workers are
/// re-raised on the caller thread after all workers exit.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let refs: Vec<&T> = items.iter().collect();
    parallel_map_owned(refs, workers, |t| f(t))
}

/// Like `parallel_map` but each item is moved into `f` and the (possibly
/// transformed) results come back in input order. This is the substrate
/// for parallel arm execution inside one bandit trial: each arm task owns
/// mutable state (component-optimizer state, ledger shard, RNG) that a
/// shared-reference `parallel_map` closure could not touch.
pub fn parallel_map_owned<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let f_ref = &f;
    let cursor_ref = &cursor;
    let inputs_ref = &inputs;
    let slots_ref = &slots;

    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || loop {
                    let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = inputs_ref[i].lock().unwrap().take().expect("item taken twice");
                    let r = f_ref(item);
                    *slots_ref[i].lock().unwrap() = Some(r);
                })
            })
            .collect();
        for h in handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker skipped a slot"))
        .collect()
}

/// Like `parallel_map` but with a progress callback invoked (from worker
/// threads) after each completed item with the number done so far.
pub fn parallel_map_progress<T, R, F, P>(items: Vec<T>, workers: usize, f: F, progress: P) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    P: Fn(usize, usize) + Sync,
{
    let done = AtomicUsize::new(0);
    let n = items.len();
    let done_ref = &done;
    let progress_ref = &progress;
    let f_ref = &f;
    parallel_map(items, workers, move |t| {
        let r = f_ref(t);
        let d = done_ref.fetch_add(1, Ordering::Relaxed) + 1;
        progress_ref(d, n);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(items, 8, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(Vec::<usize>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(vec![5, 6], 16, |&x| x);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn all_items_processed_exactly_once() {
        let counter = AtomicU64::new(0);
        let _ = parallel_map((0..500).collect::<Vec<_>>(), 7, |_| {
            counter.fetch_add(1, Ordering::Relaxed)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        let _ = parallel_map(vec![0usize, 1, 2], 2, |&x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn owned_map_preserves_order_and_moves_state() {
        // Each item carries mutable state the closure consumes and
        // returns transformed.
        let items: Vec<Vec<usize>> = (0..200).map(|i| vec![i]).collect();
        let out = parallel_map_owned(items, 8, |mut v| {
            v.push(v[0] * 2);
            v
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, [i, i * 2]);
        }
    }

    #[test]
    fn owned_map_single_worker_and_empty() {
        let out = parallel_map_owned(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<usize> = parallel_map_owned(Vec::<usize>::new(), 4, |x| x);
        assert!(empty.is_empty());
    }

    #[test]
    #[should_panic(expected = "owned boom")]
    fn owned_map_panic_propagates() {
        let _ = parallel_map_owned(vec![0usize, 1, 2], 2, |x| {
            if x == 1 {
                panic!("owned boom");
            }
            x
        });
    }

    #[test]
    fn progress_reaches_total() {
        let max_seen = AtomicUsize::new(0);
        let _ = parallel_map_progress(
            (0..100).collect::<Vec<_>>(),
            4,
            |&x| x,
            |done, total| {
                assert!(done <= total);
                max_seen.fetch_max(done, Ordering::Relaxed);
            },
        );
        assert_eq!(max_seen.load(Ordering::Relaxed), 100);
    }
}
