//! Readiness primitives for the TCP service's event loop (substrate:
//! no mio/tokio offline).
//!
//! Thin safe wrappers over raw `extern "C"` libc calls — `poll(2)` for
//! readiness multiplexing and `pipe(2)`/`fcntl(2)` for a nonblocking
//! self-wake channel — so one thread can own every connection socket and
//! sleep until *something* (a readable socket, a writable socket, or a
//! worker finishing a response) needs it. Zero new crates: the only
//! platform surface used is the stable POSIX ABI, declared inline.
//!
//! Only compiled on Unix. [`supported`] reports availability at runtime
//! so callers (the service's `--event-loop auto` switch) can fall back
//! to thread-per-connection elsewhere.

/// Whether the poll-based event loop can run on this platform.
pub fn supported() -> bool {
    cfg!(unix)
}

#[cfg(unix)]
pub use imp::{poll, PollFd, WakePipe, POLLERR, POLLHUP, POLLIN, POLLNVAL, POLLOUT};

#[cfg(unix)]
mod imp {
    use std::io;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::RawFd;

    /// Readiness bits (identical values across the Unixes we target).
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const O_NONBLOCK: c_int = 0x0004;

    #[cfg(any(target_os = "linux", target_os = "android"))]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    type NfdsT = std::os::raw::c_uint;

    /// One entry of a `poll(2)` set. `#[repr(C)]`-identical to the libc
    /// `struct pollfd`, so a `&mut [PollFd]` is passed straight through.
    #[repr(C)]
    #[derive(Clone, Copy, Debug)]
    pub struct PollFd {
        pub fd: RawFd,
        /// Requested readiness ([`POLLIN`] | [`POLLOUT`]); error
        /// conditions ([`POLLERR`]/[`POLLHUP`]/[`POLLNVAL`]) are always
        /// reported regardless.
        pub events: i16,
        /// Readiness reported by the last [`poll`] call.
        pub revents: i16,
    }

    impl PollFd {
        pub fn new(fd: RawFd, events: i16) -> PollFd {
            PollFd { fd, events, revents: 0 }
        }

        pub fn readable(&self) -> bool {
            self.revents & POLLIN != 0
        }

        pub fn writable(&self) -> bool {
            self.revents & POLLOUT != 0
        }

        pub fn hangup(&self) -> bool {
            self.revents & POLLHUP != 0
        }

        pub fn error(&self) -> bool {
            self.revents & (POLLERR | POLLNVAL) != 0
        }
    }

    /// The raw POSIX surface, declared inline (no libc crate offline).
    mod ffi {
        use super::{c_int, c_void, NfdsT, PollFd};
        extern "C" {
            pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
            pub fn pipe(fds: *mut c_int) -> c_int;
            pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
            pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
            pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
            pub fn close(fd: c_int) -> c_int;
        }
    }

    /// Block until any entry is ready or `timeout_ms` elapses (-1 =
    /// forever). Returns how many entries have nonzero `revents`;
    /// retries transparently on `EINTR`.
    pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe { ffi::poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if n >= 0 {
                return Ok(n as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }

    fn set_nonblocking(fd: RawFd) -> io::Result<()> {
        let flags = unsafe { ffi::fcntl(fd, F_GETFL, 0) };
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if unsafe { ffi::fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Self-wake channel for the event loop: worker threads call
    /// [`wake`](WakePipe::wake) after depositing a response, making the
    /// loop's `poll` return immediately instead of waiting out its
    /// timeout. Both ends are nonblocking — a full pipe means a wake is
    /// already pending, so dropping the byte is correct.
    pub struct WakePipe {
        read_fd: RawFd,
        write_fd: RawFd,
    }

    impl WakePipe {
        pub fn new() -> io::Result<WakePipe> {
            let mut fds: [c_int; 2] = [0; 2];
            if unsafe { ffi::pipe(fds.as_mut_ptr()) } != 0 {
                return Err(io::Error::last_os_error());
            }
            let pipe = WakePipe { read_fd: fds[0], write_fd: fds[1] };
            set_nonblocking(pipe.read_fd)?;
            set_nonblocking(pipe.write_fd)?;
            Ok(pipe)
        }

        /// The fd to include (with [`POLLIN`]) in the loop's poll set.
        pub fn read_fd(&self) -> RawFd {
            self.read_fd
        }

        /// Nudge the poller. Callable from any thread; never blocks.
        pub fn wake(&self) {
            let byte = [1u8];
            let _ = unsafe { ffi::write(self.write_fd, byte.as_ptr() as *const c_void, 1) };
        }

        /// Consume pending wake bytes so the next `poll` sleeps again.
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                let n =
                    unsafe { ffi::read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
                if n < buf.len() as isize {
                    break;
                }
            }
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                ffi::close(self.read_fd);
                ffi::close(self.write_fd);
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::Arc;

        #[test]
        fn wake_makes_pipe_readable_and_drain_clears_it() {
            let pipe = WakePipe::new().unwrap();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            // Nothing pending: poll times out with zero ready entries.
            assert_eq!(poll(&mut fds, 0).unwrap(), 0);
            assert!(!fds[0].readable());

            pipe.wake();
            pipe.wake(); // coalesced wakes are fine
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
            assert!(fds[0].readable());

            pipe.drain();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 0).unwrap(), 0);
        }

        #[test]
        fn wake_from_another_thread_unblocks_a_sleeping_poll() {
            let pipe = Arc::new(WakePipe::new().unwrap());
            let waker = Arc::clone(&pipe);
            let t = std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                waker.wake();
            });
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            let started = std::time::Instant::now();
            // 10 s timeout: the wake, not the timeout, must end the wait.
            assert_eq!(poll(&mut fds, 10_000).unwrap(), 1);
            assert!(started.elapsed() < std::time::Duration::from_secs(5));
            t.join().unwrap();
        }

        #[test]
        fn wake_never_blocks_even_when_the_pipe_is_full() {
            let pipe = WakePipe::new().unwrap();
            // A pipe holds ~64 KiB; far more wakes must all return.
            for _ in 0..100_000 {
                pipe.wake();
            }
            pipe.drain();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 0).unwrap(), 0, "drain must empty the pipe");
        }

        #[test]
        fn poll_reports_readiness_on_sockets() {
            use std::io::Write;
            use std::os::unix::io::AsRawFd;
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let port = listener.local_addr().unwrap().port();
            let mut client = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let (server, _) = listener.accept().unwrap();

            // Nothing sent yet: server side is not readable.
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN | POLLOUT)];
            assert!(poll(&mut fds, 0).unwrap() >= 1, "fresh socket should be writable");
            assert!(fds[0].writable());
            assert!(!fds[0].readable());

            client.write_all(b"x").unwrap();
            client.flush().unwrap();
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 2000).unwrap(), 1);
            assert!(fds[0].readable());

            // Peer close surfaces as readable (read returns 0) and/or HUP.
            drop(client);
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 2000).unwrap(), 1);
            assert!(fds[0].readable() || fds[0].hangup());
        }
    }
}
