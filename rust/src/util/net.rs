//! Readiness primitives for the TCP service's event loop (substrate:
//! no mio/tokio offline).
//!
//! Thin safe wrappers over raw `extern "C"` libc calls — `epoll(7)` on
//! Linux and `poll(2)` everywhere else for readiness multiplexing, plus
//! `pipe(2)`/`fcntl(2)` for a nonblocking self-wake channel — so each
//! reactor thread can own its disjoint subset of connection sockets and
//! sleep until *something* (a readable socket, a writable socket, a
//! worker finishing a response, or the acceptor handing off a new
//! connection) needs it. Every [`Readiness`] instance and [`WakePipe`]
//! is independent — the sharded service creates one of each per
//! reactor, plus a wake pipe the reactors ring to unpark the acceptor.
//! Zero new crates: the only platform surface used is the stable
//! POSIX/Linux ABI, declared inline.
//!
//! Two registration-based backends sit behind one [`Readiness`] facade:
//!
//! * [`Epoll`] (Linux): sockets are registered **once**
//!   (`epoll_ctl(ADD)`) and their interest updated only on state
//!   transitions (`MOD`); a wakeup costs O(ready events) no matter how
//!   many sockets are open. This is what lets one loop hold 10k–100k
//!   idle keep-alive connections for the price of the active few.
//! * [`PollSet`] (portable Unix): the same register/modify/deregister
//!   API over a **persistent** `pollfd` array — entries are updated in
//!   place on interest transitions instead of the array being rebuilt
//!   every iteration, so the per-wakeup cost is one O(open) kernel scan
//!   with zero allocation, not an O(open) rebuild *plus* the scan.
//!
//! Both deliver readiness as portable [`Event`]s into a caller-owned
//! scratch vec, so the serving loop is written once and differentially
//! tested across backends. [`supported`]/[`epoll_supported`] report
//! availability at runtime; [`nofile_limit`] exposes
//! `getrlimit(RLIMIT_NOFILE)` so callers can clamp connection caps to
//! what the fd table actually allows.

/// Whether any readiness backend (poll at minimum) can run here.
pub fn supported() -> bool {
    cfg!(unix)
}

/// Whether the epoll backend can run on this platform.
pub fn epoll_supported() -> bool {
    cfg!(any(target_os = "linux", target_os = "android"))
}

#[cfg(unix)]
pub use imp::{
    nofile_limit, poll, raise_nofile_limit, Event, PollFd, PollSet, Readiness, WakePipe, POLLERR,
    POLLHUP, POLLIN, POLLNVAL, POLLOUT,
};

#[cfg(any(target_os = "linux", target_os = "android"))]
pub use imp::Epoll;

#[cfg(unix)]
mod imp {
    use std::io;
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::RawFd;

    /// Readiness bits (identical values across the Unixes we target).
    /// Also the portable *interest* language of [`Readiness`]: callers
    /// ask for `POLLIN`/`POLLOUT` and receive [`Event`]s carrying the
    /// same bits, whichever backend produced them.
    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    const F_GETFL: c_int = 3;
    const F_SETFL: c_int = 4;
    #[cfg(any(target_os = "linux", target_os = "android"))]
    const O_NONBLOCK: c_int = 0o4000;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const O_NONBLOCK: c_int = 0x0004;

    #[cfg(any(target_os = "linux", target_os = "android"))]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    type NfdsT = std::os::raw::c_uint;

    #[cfg(any(target_os = "linux", target_os = "android"))]
    const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const RLIMIT_NOFILE: c_int = 8;

    /// One entry of a `poll(2)` set. `#[repr(C)]`-identical to the libc
    /// `struct pollfd`, so a `&mut [PollFd]` is passed straight through.
    #[repr(C)]
    #[derive(Clone, Copy, Debug)]
    pub struct PollFd {
        pub fd: RawFd,
        /// Requested readiness ([`POLLIN`] | [`POLLOUT`]); error
        /// conditions ([`POLLERR`]/[`POLLHUP`]/[`POLLNVAL`]) are always
        /// reported regardless.
        pub events: i16,
        /// Readiness reported by the last [`poll`] call.
        pub revents: i16,
    }

    impl PollFd {
        pub fn new(fd: RawFd, events: i16) -> PollFd {
            PollFd { fd, events, revents: 0 }
        }

        pub fn readable(&self) -> bool {
            self.revents & POLLIN != 0
        }

        pub fn writable(&self) -> bool {
            self.revents & POLLOUT != 0
        }

        pub fn hangup(&self) -> bool {
            self.revents & POLLHUP != 0
        }

        pub fn error(&self) -> bool {
            self.revents & (POLLERR | POLLNVAL) != 0
        }
    }

    /// `getrlimit(2)`'s `struct rlimit` (both fields `rlim_t`, 64-bit on
    /// every 64-bit Unix we target).
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    /// `epoll_event` — packed on x86-64 (kernel ABI), natural alignment
    /// elsewhere.
    #[cfg(any(target_os = "linux", target_os = "android"))]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    /// The raw POSIX/Linux surface, declared inline (no libc crate
    /// offline).
    mod ffi {
        use super::{c_int, c_void, NfdsT, PollFd, RLimit};
        extern "C" {
            pub fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
            pub fn pipe(fds: *mut c_int) -> c_int;
            pub fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
            pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
            pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
            pub fn close(fd: c_int) -> c_int;
            pub fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
            pub fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
        }

        #[cfg(any(target_os = "linux", target_os = "android"))]
        extern "C" {
            pub fn epoll_create1(flags: c_int) -> c_int;
            pub fn epoll_ctl(
                epfd: c_int,
                op: c_int,
                fd: c_int,
                event: *mut super::EpollEvent,
            ) -> c_int;
            pub fn epoll_wait(
                epfd: c_int,
                events: *mut super::EpollEvent,
                maxevents: c_int,
                timeout: c_int,
            ) -> c_int;
        }
    }

    /// The process's open-file-descriptor limit as `(soft, hard)`.
    /// `u64::MAX`-ish values mean "unlimited"; callers that clamp with
    /// `min()` need no special case.
    pub fn nofile_limit() -> Option<(u64, u64)> {
        let mut r = RLimit { cur: 0, max: 0 };
        if unsafe { ffi::getrlimit(RLIMIT_NOFILE, &mut r) } == 0 {
            Some((r.cur, r.max))
        } else {
            None
        }
    }

    /// Raise the soft `RLIMIT_NOFILE` toward `target` (never past the
    /// hard limit) and return the resulting `(soft, hard)` pair. Meant
    /// for socket-heavy benches that park tens of thousands of
    /// connections in one process; the service itself only probes. A
    /// refused `setrlimit` is not an error — the unchanged limits come
    /// back and the caller clamps to them as usual.
    pub fn raise_nofile_limit(target: u64) -> Option<(u64, u64)> {
        let (soft, hard) = nofile_limit()?;
        if soft >= target {
            return Some((soft, hard));
        }
        let lifted = RLimit { cur: target.min(hard), max: hard };
        if unsafe { ffi::setrlimit(RLIMIT_NOFILE, &lifted) } == 0 {
            Some((lifted.cur, hard))
        } else {
            Some((soft, hard))
        }
    }

    /// Block until any entry is ready or `timeout_ms` elapses (-1 =
    /// forever). Returns how many entries have nonzero `revents`;
    /// retries transparently on `EINTR`.
    pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe { ffi::poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if n >= 0 {
                return Ok(n as usize);
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }

    fn set_nonblocking(fd: RawFd) -> io::Result<()> {
        let flags = unsafe { ffi::fcntl(fd, F_GETFL, 0) };
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if unsafe { ffi::fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// One readiness report from a [`Readiness`] backend: the token the
    /// fd was registered under plus its ready bits (in [`POLLIN`]-family
    /// encoding regardless of backend).
    #[derive(Clone, Copy, Debug)]
    pub struct Event {
        pub token: u64,
        pub flags: i16,
    }

    impl Event {
        pub fn readable(&self) -> bool {
            self.flags & POLLIN != 0
        }

        pub fn writable(&self) -> bool {
            self.flags & POLLOUT != 0
        }

        pub fn hangup(&self) -> bool {
            self.flags & POLLHUP != 0
        }

        pub fn error(&self) -> bool {
            self.flags & (POLLERR | POLLNVAL) != 0
        }
    }

    /// Registration-based readiness over a **persistent** `poll(2)` set:
    /// the portable fallback behind [`Readiness`].
    ///
    /// The `pollfd` array and its token mirror live across wakeups;
    /// interest transitions update one entry in place and deregistration
    /// swap-removes, so the steady state allocates nothing and touches
    /// only the entries whose interest actually changed. The kernel scan
    /// itself is still O(registered) per wakeup — that linear cost is
    /// the backend's documented limitation (and what the epoll backend
    /// removes), not an implementation artifact.
    pub struct PollSet {
        fds: Vec<PollFd>,
        tokens: Vec<u64>,
        slot_of: std::collections::HashMap<u64, usize>,
    }

    impl PollSet {
        pub fn new() -> io::Result<PollSet> {
            Ok(PollSet {
                fds: Vec::new(),
                tokens: Vec::new(),
                slot_of: std::collections::HashMap::new(),
            })
        }

        fn slot(&self, token: u64) -> io::Result<usize> {
            self.slot_of
                .get(&token)
                .copied()
                .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "token not registered"))
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            if self.slot_of.contains_key(&token) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "token registered"));
            }
            self.slot_of.insert(token, self.fds.len());
            self.fds.push(PollFd::new(fd, interest));
            self.tokens.push(token);
            Ok(())
        }

        pub fn modify(&mut self, _fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            let slot = self.slot(token)?;
            self.fds[slot].events = interest;
            Ok(())
        }

        pub fn deregister(&mut self, _fd: RawFd, token: u64) -> io::Result<()> {
            let slot = self.slot(token)?;
            self.slot_of.remove(&token);
            self.fds.swap_remove(slot);
            self.tokens.swap_remove(slot);
            if slot < self.tokens.len() {
                self.slot_of.insert(self.tokens[slot], slot);
            }
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let mut ready = poll(&mut self.fds, timeout_ms)?;
            for (i, fd) in self.fds.iter_mut().enumerate() {
                if ready == 0 {
                    break;
                }
                if fd.revents != 0 {
                    out.push(Event { token: self.tokens[i], flags: fd.revents });
                    fd.revents = 0;
                    ready -= 1;
                }
            }
            Ok(())
        }
    }

    /// Registration-based readiness over `epoll(7)`: sockets are added
    /// once and interest is updated only on state transitions, so a
    /// wakeup costs O(ready events) instead of O(open sockets).
    /// Level-triggered (the default), matching `poll(2)` semantics so
    /// the two backends are behaviorally interchangeable.
    #[cfg(any(target_os = "linux", target_os = "android"))]
    pub struct Epoll {
        epfd: RawFd,
        /// Kernel-filled scratch, reused across wakeups.
        buf: Vec<EpollEvent>,
    }

    #[cfg(any(target_os = "linux", target_os = "android"))]
    impl Epoll {
        const EPOLL_CLOEXEC: c_int = 0o2000000;
        const CTL_ADD: c_int = 1;
        const CTL_DEL: c_int = 2;
        const CTL_MOD: c_int = 3;
        const EPOLLIN: u32 = 0x001;
        const EPOLLOUT: u32 = 0x004;
        const EPOLLERR: u32 = 0x008;
        const EPOLLHUP: u32 = 0x010;
        /// Default ready events drained per `epoll_wait`; more stay
        /// queued for the next wakeup (level-triggered), so this bounds
        /// per-wakeup work without ever losing readiness.
        const WAIT_BATCH: usize = 1024;
        /// Floor and ceiling for [`with_batch`](Self::with_batch): the
        /// batch never shrinks below a useful burst nor balloons the
        /// scratch buffer past ~1 MiB (12 bytes/event packed).
        const MIN_BATCH: usize = 64;
        const MAX_BATCH: usize = 65_536;

        pub fn new() -> io::Result<Epoll> {
            Self::with_batch(Self::WAIT_BATCH)
        }

        /// An epoll instance whose per-wait event batch is sized to the
        /// caller's expected fd population (clamped to
        /// [`MIN_BATCH`](Self::MIN_BATCH)..=[`MAX_BATCH`]
        /// (Self::MAX_BATCH)). A loop serving 100k registered sockets
        /// under sustained high-active load drains a full readiness
        /// burst in one syscall instead of 1024-event slices, each of
        /// which is a separate wakeup.
        pub fn with_batch(batch: usize) -> io::Result<Epoll> {
            let epfd = unsafe { ffi::epoll_create1(Self::EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            let batch = batch.clamp(Self::MIN_BATCH, Self::MAX_BATCH);
            Ok(Epoll { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; batch] })
        }

        /// Events one `wait` call can return (the scratch buffer size).
        pub fn batch(&self) -> usize {
            self.buf.len()
        }

        fn interest_bits(interest: i16) -> u32 {
            let mut ev = 0;
            if interest & POLLIN != 0 {
                ev |= Self::EPOLLIN;
            }
            if interest & POLLOUT != 0 {
                ev |= Self::EPOLLOUT;
            }
            ev
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            let mut ev = EpollEvent { events: Self::interest_bits(interest), data: token };
            let rc = unsafe { ffi::epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            self.ctl(Self::CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            self.ctl(Self::CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
            self.ctl(Self::CTL_DEL, fd, token, 0)
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let n = loop {
                let n = unsafe {
                    ffi::epoll_wait(
                        self.epfd,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as c_int,
                        timeout_ms,
                    )
                };
                if n >= 0 {
                    break n as usize;
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            };
            for ev in &self.buf[..n] {
                // Copy out of the (possibly packed) struct before use.
                let (bits, token) = (ev.events, ev.data);
                let mut flags = 0i16;
                if bits & Self::EPOLLIN != 0 {
                    flags |= POLLIN;
                }
                if bits & Self::EPOLLOUT != 0 {
                    flags |= POLLOUT;
                }
                if bits & Self::EPOLLERR != 0 {
                    flags |= POLLERR;
                }
                if bits & Self::EPOLLHUP != 0 {
                    flags |= POLLHUP;
                }
                out.push(Event { token, flags });
            }
            Ok(())
        }
    }

    #[cfg(any(target_os = "linux", target_os = "android"))]
    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                ffi::close(self.epfd);
            }
        }
    }

    /// The serving loop's readiness facade: one registration API, two
    /// interchangeable backends. Construction order of preference is the
    /// caller's business ([`Readiness::epoll`] where supported,
    /// [`Readiness::poll_set`] as the portable fallback); everything
    /// after construction is backend-agnostic, which is what makes the
    /// epoll/poll transports differentially testable byte-for-byte.
    pub enum Readiness {
        #[cfg(any(target_os = "linux", target_os = "android"))]
        Epoll(Epoll),
        Poll(PollSet),
    }

    impl Readiness {
        /// The O(ready)-per-wakeup backend; `None` off Linux.
        pub fn epoll() -> Option<io::Result<Readiness>> {
            #[cfg(any(target_os = "linux", target_os = "android"))]
            {
                Some(Epoll::new().map(Readiness::Epoll))
            }
            #[cfg(not(any(target_os = "linux", target_os = "android")))]
            {
                None
            }
        }

        /// [`epoll`](Self::epoll) with the per-wait event batch sized to
        /// the caller's expected fd population (see
        /// [`Epoll::with_batch`]); `None` off Linux.
        pub fn epoll_with_batch(batch: usize) -> Option<io::Result<Readiness>> {
            #[cfg(any(target_os = "linux", target_os = "android"))]
            {
                Some(Epoll::with_batch(batch).map(Readiness::Epoll))
            }
            #[cfg(not(any(target_os = "linux", target_os = "android")))]
            {
                let _ = batch;
                None
            }
        }

        /// The portable poll(2) backend.
        pub fn poll_set() -> io::Result<Readiness> {
            PollSet::new().map(Readiness::Poll)
        }

        /// Short name for logs/stats ("epoll" | "poll").
        pub fn name(&self) -> &'static str {
            match self {
                #[cfg(any(target_os = "linux", target_os = "android"))]
                Readiness::Epoll(_) => "epoll",
                Readiness::Poll(_) => "poll",
            }
        }

        /// Start watching `fd` under `token` with the given interest
        /// bits ([`POLLIN`] | [`POLLOUT`]; 0 = errors/hangup only).
        pub fn register(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            match self {
                #[cfg(any(target_os = "linux", target_os = "android"))]
                Readiness::Epoll(e) => e.register(fd, token, interest),
                Readiness::Poll(p) => p.register(fd, token, interest),
            }
        }

        /// Change the interest of a registered fd (call only on actual
        /// transitions — that is the whole point of registration).
        pub fn modify(&mut self, fd: RawFd, token: u64, interest: i16) -> io::Result<()> {
            match self {
                #[cfg(any(target_os = "linux", target_os = "android"))]
                Readiness::Epoll(e) => e.modify(fd, token, interest),
                Readiness::Poll(p) => p.modify(fd, token, interest),
            }
        }

        /// Stop watching a registered fd (before closing it).
        pub fn deregister(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
            match self {
                #[cfg(any(target_os = "linux", target_os = "android"))]
                Readiness::Epoll(e) => e.deregister(fd, token),
                Readiness::Poll(p) => p.deregister(fd, token),
            }
        }

        /// Collect ready events into `out` (cleared first; the caller
        /// owns and reuses the scratch), waiting at most `timeout_ms`
        /// (-1 = forever). Retries transparently on `EINTR`.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            match self {
                #[cfg(any(target_os = "linux", target_os = "android"))]
                Readiness::Epoll(e) => e.wait(out, timeout_ms),
                Readiness::Poll(p) => p.wait(out, timeout_ms),
            }
        }
    }

    /// Self-wake channel for a readiness loop: worker threads call
    /// [`wake`](WakePipe::wake) after depositing a response (and the
    /// acceptor after handing off a socket), making the owning
    /// reactor's `poll` return immediately instead of waiting out its
    /// timeout. Each reactor owns exactly one; the acceptor owns one
    /// more that reactors ring when a closed connection frees a slot.
    /// Both ends are nonblocking — a full pipe means a wake is already
    /// pending, so dropping the byte is correct.
    pub struct WakePipe {
        read_fd: RawFd,
        write_fd: RawFd,
    }

    // Wake pipes cross thread boundaries by design (workers → reactor,
    // acceptor → reactor, reactors → acceptor). The fds are plain
    // integers so the auto traits hold today; this assertion keeps a
    // future field addition from silently revoking them.
    const _: () = {
        const fn require_send_sync<T: Send + Sync>() {}
        require_send_sync::<WakePipe>()
    };

    impl WakePipe {
        pub fn new() -> io::Result<WakePipe> {
            let mut fds: [c_int; 2] = [0; 2];
            if unsafe { ffi::pipe(fds.as_mut_ptr()) } != 0 {
                return Err(io::Error::last_os_error());
            }
            let pipe = WakePipe { read_fd: fds[0], write_fd: fds[1] };
            set_nonblocking(pipe.read_fd)?;
            set_nonblocking(pipe.write_fd)?;
            Ok(pipe)
        }

        /// The fd to include (with [`POLLIN`]) in the loop's poll set.
        pub fn read_fd(&self) -> RawFd {
            self.read_fd
        }

        /// Nudge the poller. Callable from any thread; never blocks.
        pub fn wake(&self) {
            let byte = [1u8];
            let _ = unsafe { ffi::write(self.write_fd, byte.as_ptr() as *const c_void, 1) };
        }

        /// Consume pending wake bytes so the next `poll` sleeps again.
        pub fn drain(&self) {
            let mut buf = [0u8; 64];
            loop {
                let n =
                    unsafe { ffi::read(self.read_fd, buf.as_mut_ptr() as *mut c_void, buf.len()) };
                if n < buf.len() as isize {
                    break;
                }
            }
        }
    }

    impl Drop for WakePipe {
        fn drop(&mut self) {
            unsafe {
                ffi::close(self.read_fd);
                ffi::close(self.write_fd);
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::Arc;

        #[test]
        fn wake_makes_pipe_readable_and_drain_clears_it() {
            let pipe = WakePipe::new().unwrap();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            // Nothing pending: poll times out with zero ready entries.
            assert_eq!(poll(&mut fds, 0).unwrap(), 0);
            assert!(!fds[0].readable());

            pipe.wake();
            pipe.wake(); // coalesced wakes are fine
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
            assert!(fds[0].readable());

            pipe.drain();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 0).unwrap(), 0);
        }

        #[test]
        fn wake_from_another_thread_unblocks_a_sleeping_poll() {
            let pipe = Arc::new(WakePipe::new().unwrap());
            let waker = Arc::clone(&pipe);
            let t = std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(20));
                waker.wake();
            });
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            let started = std::time::Instant::now();
            // 10 s timeout: the wake, not the timeout, must end the wait.
            assert_eq!(poll(&mut fds, 10_000).unwrap(), 1);
            assert!(started.elapsed() < std::time::Duration::from_secs(5));
            t.join().unwrap();
        }

        #[test]
        fn wake_never_blocks_even_when_the_pipe_is_full() {
            let pipe = WakePipe::new().unwrap();
            // A pipe holds ~64 KiB; far more wakes must all return.
            for _ in 0..100_000 {
                pipe.wake();
            }
            pipe.drain();
            let mut fds = [PollFd::new(pipe.read_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 0).unwrap(), 0, "drain must empty the pipe");
        }

        #[test]
        fn poll_reports_readiness_on_sockets() {
            use std::io::Write;
            use std::os::unix::io::AsRawFd;
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let port = listener.local_addr().unwrap().port();
            let mut client = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let (server, _) = listener.accept().unwrap();

            // Nothing sent yet: server side is not readable.
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN | POLLOUT)];
            assert!(poll(&mut fds, 0).unwrap() >= 1, "fresh socket should be writable");
            assert!(fds[0].writable());
            assert!(!fds[0].readable());

            client.write_all(b"x").unwrap();
            client.flush().unwrap();
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 2000).unwrap(), 1);
            assert!(fds[0].readable());

            // Peer close surfaces as readable (read returns 0) and/or HUP.
            drop(client);
            let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
            assert_eq!(poll(&mut fds, 2000).unwrap(), 1);
            assert!(fds[0].readable() || fds[0].hangup());
        }

        #[test]
        fn nofile_limit_is_probed() {
            let (soft, hard) = nofile_limit().expect("getrlimit(RLIMIT_NOFILE)");
            assert!(soft >= 64, "implausibly small fd limit: {soft}");
            assert!(hard >= soft);
        }

        /// Every available backend reports the same readiness story for
        /// the same socket choreography: registration, interest
        /// transitions, peer data, deregistration.
        #[test]
        fn readiness_backends_report_identical_transitions() {
            use std::io::Write;
            use std::os::unix::io::AsRawFd;

            let mut backends: Vec<Readiness> = vec![Readiness::poll_set().unwrap()];
            if let Some(e) = Readiness::epoll() {
                backends.push(e.unwrap());
            }
            #[cfg(any(target_os = "linux", target_os = "android"))]
            assert_eq!(backends.len(), 2, "epoll must be available on Linux");

            for mut r in backends {
                let name = r.name();
                let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
                let port = listener.local_addr().unwrap().port();
                let mut client = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
                let (server, _) = listener.accept().unwrap();
                server.set_nonblocking(true).unwrap();
                let fd = server.as_raw_fd();
                let mut events = Vec::new();

                // Read-only interest on a quiet socket: silence.
                r.register(fd, 7, POLLIN).unwrap();
                r.wait(&mut events, 0).unwrap();
                assert!(events.is_empty(), "{name}: quiet socket reported {events:?}");

                // Peer data arrives: readable under the right token.
                client.write_all(b"x").unwrap();
                client.flush().unwrap();
                r.wait(&mut events, 2000).unwrap();
                assert_eq!(events.len(), 1, "{name}: {events:?}");
                assert_eq!(events[0].token, 7);
                assert!(events[0].readable());
                assert!(!events[0].writable(), "{name}: writable not requested");

                // Interest transition to write-armed: immediately
                // writable (and still readable — unread data pends).
                r.modify(fd, 7, POLLIN | POLLOUT).unwrap();
                r.wait(&mut events, 2000).unwrap();
                assert_eq!(events.len(), 1, "{name}: {events:?}");
                assert!(events[0].writable(), "{name}");
                assert!(events[0].readable(), "{name}: level-triggered data must re-report");

                // Interest 0: data still unread, but nothing requested.
                r.modify(fd, 7, 0).unwrap();
                r.wait(&mut events, 0).unwrap();
                assert!(
                    events.iter().all(|e| e.flags & (POLLIN | POLLOUT) == 0),
                    "{name}: paused socket reported requested bits: {events:?}"
                );

                // Peer close surfaces even at interest 0 (HUP class) or
                // once read interest is restored.
                drop(client);
                r.modify(fd, 7, POLLIN).unwrap();
                r.wait(&mut events, 2000).unwrap();
                assert_eq!(events.len(), 1, "{name}: {events:?}");
                assert!(events[0].readable() || events[0].hangup(), "{name}");

                // Deregistered: silent again, and re-registration works.
                r.deregister(fd, 7).unwrap();
                r.wait(&mut events, 0).unwrap();
                assert!(events.is_empty(), "{name}: deregistered fd reported {events:?}");
                r.register(fd, 9, POLLIN).unwrap();
                r.wait(&mut events, 2000).unwrap();
                assert_eq!(events.len(), 1, "{name}");
                assert_eq!(events[0].token, 9, "{name}");
            }
        }

        /// PollSet keeps its token map consistent across swap-removes.
        #[test]
        fn poll_set_deregister_swaps_tokens_correctly() {
            use std::os::unix::io::AsRawFd;
            let pipes: Vec<WakePipe> = (0..4).map(|_| WakePipe::new().unwrap()).collect();
            let mut set = PollSet::new().unwrap();
            for (i, p) in pipes.iter().enumerate() {
                set.register(p.read_fd(), i as u64, POLLIN).unwrap();
            }
            // Remove the first entry: the last one swaps into its slot.
            set.deregister(pipes[0].read_fd(), 0).unwrap();
            // The swapped entry must still be reachable by token.
            pipes[3].wake();
            let mut events = Vec::new();
            set.wait(&mut events, 1000).unwrap();
            assert_eq!(events.len(), 1);
            assert_eq!(events[0].token, 3);
            assert!(events[0].readable());
            // And modifying it by token touches the right fd.
            set.modify(pipes[3].read_fd(), 3, 0).unwrap();
            pipes[3].wake();
            set.wait(&mut events, 0).unwrap();
            assert!(events.is_empty(), "interest 0 must silence the swapped entry");
            // Double-registering a live token errors; unknown tokens err.
            assert!(set.register(pipes[1].read_fd(), 1, POLLIN).is_err());
            assert!(set.modify(0, 99, POLLIN).is_err());
            assert!(set.deregister(0, 99).is_err());
        }
    }
}
