//! Summary statistics used by the metrics / report layers.
//!
//! Everything here operates on plain `&[f64]` and is careful about the two
//! conventions the paper relies on: *linear-interpolation percentiles* (for
//! p90 measurement modes) and *Tukey box-plot statistics* (Figure 4:
//! median, IQR box, 1.5-IQR whiskers).

/// Arithmetic mean. Returns NaN on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator); 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

fn sorted_copy(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats input"));
    v
}

/// Linear-interpolation percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
    let v = sorted_copy(xs);
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Tukey box-plot summary (Figure 4's rendering contract).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    /// Smallest value >= q1 - 1.5*IQR.
    pub whisker_lo: f64,
    /// Largest value <= q3 + 1.5*IQR.
    pub whisker_hi: f64,
    pub n: usize,
    pub outliers: usize,
}

impl BoxStats {
    pub fn compute(xs: &[f64]) -> BoxStats {
        assert!(!xs.is_empty(), "BoxStats of empty slice");
        let v = sorted_copy(xs);
        let q1 = percentile_sorted(&v, 25.0);
        let q3 = percentile_sorted(&v, 75.0);
        let med = percentile_sorted(&v, 50.0);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = v.iter().copied().find(|&x| x >= lo_fence).unwrap_or(v[0]);
        let whisker_hi = v
            .iter()
            .rev()
            .copied()
            .find(|&x| x <= hi_fence)
            .unwrap_or(v[v.len() - 1]);
        let outliers = v.iter().filter(|&&x| x < lo_fence || x > hi_fence).count();
        BoxStats { q1, median: med, q3, whisker_lo, whisker_hi, n: v.len(), outliers }
    }
}

/// Welford online mean/variance accumulator (used by long-running benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(percentile(&xs, 25.0), 20.0);
        assert!((percentile(&xs, 90.0) - 46.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_matches_hand_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // population var 4 -> sample var 4*8/7
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn box_stats_tukey() {
        let xs: Vec<f64> = (1..=11).map(|i| i as f64).collect();
        let b = BoxStats::compute(&xs);
        assert_eq!(b.median, 6.0);
        assert_eq!(b.q1, 3.5);
        assert_eq!(b.q3, 8.5);
        assert_eq!(b.whisker_lo, 1.0);
        assert_eq!(b.whisker_hi, 11.0);
        assert_eq!(b.outliers, 0);
    }

    #[test]
    fn box_stats_flags_outlier() {
        let mut xs: Vec<f64> = (1..=11).map(|i| i as f64).collect();
        xs.push(100.0);
        let b = BoxStats::compute(&xs);
        assert_eq!(b.outliers, 1);
        assert!(b.whisker_hi <= 11.0 + 1e-9);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.stddev() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 7.5];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.5);
    }
}
