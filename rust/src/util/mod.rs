//! Shared substrates: deterministic RNG, statistics, JSON/CSV codecs,
//! a work-queue thread pool, poll(2) readiness primitives, CLI parsing
//! and deterministic fault injection. These stand in for the crates (serde, rayon, clap, mio, ...)
//! that are unavailable in the offline build environment — see DESIGN.md
//! §Substitutions.

pub mod cancel;
pub mod chaos;
pub mod cli;
pub mod csv;
pub mod json;
pub mod net;
pub mod rng;
pub mod stats;
pub mod threadpool;
