//! Shared substrates: deterministic RNG, statistics, JSON/CSV codecs,
//! a work-queue thread pool and CLI parsing. These stand in for the crates
//! (serde, rayon, clap, ...) that are unavailable in the offline build
//! environment — see DESIGN.md §Substitutions.

pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
