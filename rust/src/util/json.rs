//! Minimal JSON codec (substrate: no serde available offline).
//!
//! Used for the artifact manifest, experiment specs, result files and the
//! TCP service protocol. Objects preserve insertion order so emitted files
//! are deterministic and diff-friendly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number. Rejects negatives, non-integers,
    /// non-finite values, and magnitudes above 2^53 (f64's exact-integer
    /// ceiling): a request-supplied `1e300` must produce an error, not
    /// silently saturate to `usize::MAX`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 9_007_199_254_740_992.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object entries as a map (for iteration in key order).
    pub fn entries(&self) -> Option<BTreeMap<&str, &Value>> {
        match self {
            Value::Obj(kv) => Some(kv.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no inf/nan; encode as null (documented lossy corner).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

impl Value {
    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => escape(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    v.write(out, indent, pretty);
                }
                out.push(']');
            }
            Value::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    escape(k, out);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !kv.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// ---------------------------------------------------------------------------
// Parser

pub fn parse(input: &str) -> Result<Value, String> {
    parse_bytes(input.as_bytes())
}

/// Parse straight from bytes (the wire path's entry point): UTF-8 is
/// validated lazily, only inside string contents, so a frame never pays
/// a separate whole-buffer validation pass before parsing.
pub fn parse_bytes(input: &[u8]) -> Result<Value, String> {
    let mut p = Parser { b: input, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Unescaped run: scan to the next quote or escape
                    // and push the whole run at once, validated as
                    // UTF-8 in one pass (scanning per character used to
                    // re-validate the entire remaining input each time,
                    // making long strings quadratic).
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    let run = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| "invalid utf-8 in string")?;
                    s.push_str(run);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Value::obj(vec![
            ("name", "multi-cloud".into()),
            ("n", 42usize.into()),
            ("pi", 3.25.into()),
            ("ok", true.into()),
            ("none", Value::Null),
            ("arr", vec![1.0, 2.0, 3.0].into()),
            ("obj", Value::obj(vec![("k", "v".into())])),
        ]);
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
        let p = v.to_string_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn parses_manifest_like_input() {
        let s = r#"{"version": 2, "graphs": {"gp": {"file": "gp.hlo.txt", "outputs": ["mean","std"]}}}"#;
        let v = parse(s).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(2));
        let gp = v.get("graphs").unwrap().get("gp").unwrap();
        assert_eq!(gp.get("file").unwrap().as_str(), Some("gp.hlo.txt"));
        assert_eq!(gp.get("outputs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::str("a\"b\\c\nd\te\u{1}");
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Value::Num(1e16).to_string_compact(), "10000000000000000");
    }

    #[test]
    fn as_usize_rejects_malformed_numerics() {
        // Negatives, fractions, non-finite, and beyond-2^53 values all
        // fail instead of silently truncating or saturating.
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Num(1.5).as_usize(), None);
        assert_eq!(Value::Num(f64::NAN).as_usize(), None);
        assert_eq!(Value::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Value::Num(1e300).as_usize(), None);
        assert_eq!(Value::Num(9_007_199_254_740_992.0).as_usize(), Some(9_007_199_254_740_992));
        assert_eq!(parse("1e300").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn nonfinite_encodes_null() {
        assert_eq!(Value::Num(f64::NAN).to_string_compact(), "null");
    }

    /// `parse_bytes` is `parse` without the up-front UTF-8 pass: same
    /// values, same error strings on valid UTF-8, and a dedicated error
    /// when string contents are not UTF-8.
    #[test]
    fn parse_bytes_matches_parse() {
        for s in [
            r#"{"op":"ping","n":3,"arr":[1,2],"s":"café ☕ \n"}"#,
            "not json",
            r#"{"a" 1}"#,
            "",
        ] {
            assert_eq!(parse_bytes(s.as_bytes()), parse(s), "{s}");
        }
        assert_eq!(
            parse_bytes(b"{\"k\":\"a\xff\xfeb\"}"),
            Err("invalid utf-8 in string".to_string())
        );
    }

    /// Long strings parse in linear time (the run scanner); a smoke
    /// check that a 1 MiB string parses at all and roundtrips.
    #[test]
    fn long_strings_parse_and_roundtrip() {
        let body: String = std::iter::repeat("abcdefgh").take(128 * 1024).collect();
        let input = format!(r#"{{"k":"{body}"}}"#);
        let v = parse_bytes(input.as_bytes()).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(body.as_str()));
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }
}
