//! Minimal CSV codec (substrate) for the offline benchmark dataset and
//! figure data emitted for external plotting. RFC-4180-style quoting.

/// Escape one field if needed.
fn write_field(f: &str, out: &mut String) {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        out.push('"');
        for c in f.chars() {
            if c == '"' {
                out.push('"');
            }
            out.push(c);
        }
        out.push('"');
    } else {
        out.push_str(f);
    }
}

/// Serialize rows (first row is typically the header).
pub fn write_rows(rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    for row in rows {
        for (i, f) in row.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_field(f, &mut out);
        }
        out.push('\n');
    }
    out
}

/// Parse CSV text into rows of fields. Handles quoted fields with embedded
/// commas/newlines/escaped quotes. Skips a trailing empty line.
pub fn parse(input: &str) -> Result<Vec<Vec<String>>, String> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = input.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        return Err("quote inside unquoted field".into());
                    }
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {} // tolerate CRLF
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quoted field".into());
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

/// A header-indexed view over parsed CSV rows.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn parse(input: &str) -> Result<Table, String> {
        let mut rows = parse(input)?;
        if rows.is_empty() {
            return Err("empty csv".into());
        }
        let header = rows.remove(0);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != header.len() {
                return Err(format!(
                    "row {} has {} fields, header has {}",
                    i + 2,
                    r.len(),
                    header.len()
                ));
            }
        }
        Ok(Table { header, rows })
    }

    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    pub fn get<'a>(&'a self, row: &'a [String], name: &str) -> Option<&'a str> {
        self.col(name).map(|i| row[i].as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let rows = vec![
            vec!["a".to_string(), "b".to_string()],
            vec!["1".to_string(), "2".to_string()],
        ];
        let s = write_rows(&rows);
        assert_eq!(parse(&s).unwrap(), rows);
    }

    #[test]
    fn roundtrip_quoted() {
        let rows = vec![vec![
            "with,comma".to_string(),
            "with\"quote".to_string(),
            "with\nnewline".to_string(),
        ]];
        let s = write_rows(&rows);
        assert_eq!(parse(&s).unwrap(), rows);
    }

    #[test]
    fn crlf_tolerated() {
        assert_eq!(
            parse("a,b\r\n1,2\r\n").unwrap(),
            vec![vec!["a".to_string(), "b".to_string()], vec!["1".to_string(), "2".to_string()]]
        );
    }

    #[test]
    fn table_header_lookup() {
        let t = Table::parse("x,y\n1,2\n3,4\n").unwrap();
        assert_eq!(t.col("y"), Some(1));
        assert_eq!(t.get(&t.rows[1], "y"), Some("4"));
    }

    #[test]
    fn table_rejects_ragged_rows() {
        assert!(Table::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn rejects_bad_quoting() {
        assert!(parse("ab\"c,d\n").is_err());
        assert!(parse("\"unterminated\n").is_err());
    }
}
