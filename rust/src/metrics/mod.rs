//! Evaluation metrics: regret (§IV-B) and production savings (§IV-E).

use crate::util::stats;

/// Relative distance of the chosen configuration's ground-truth value to
/// the true minimum: (v - v*) / v*. Zero iff the optimum was found.
pub fn regret(chosen_value: f64, true_min: f64) -> f64 {
    assert!(true_min > 0.0, "true_min must be positive");
    (chosen_value - true_min) / true_min
}

/// Savings of an optimized deployment over the random-choice strategy
/// (§IV-E):
///
///   S = (N*R_rand - (C_opt + N*R_opt)) / (N*R_rand)
///
/// * `c_opt`  — one-time search expense (sum of the target metric over
///   every configuration the optimizer evaluated);
/// * `r_opt`  — per-run expense of the returned configuration;
/// * `r_rand` — expected per-run expense of a uniformly random
///   configuration;
/// * `n_runs` — production runs amortizing the search.
pub fn savings(c_opt: f64, r_opt: f64, r_rand: f64, n_runs: usize) -> f64 {
    let n = n_runs as f64;
    assert!(r_rand > 0.0 && n > 0.0);
    (n * r_rand - (c_opt + n * r_opt)) / (n * r_rand)
}

/// Aggregate per-(workload, seed) regrets into the figure's scalar: mean
/// over seeds, then mean over workloads (each inner slice = one workload's
/// seeds).
pub fn mean_regret_over_workloads(per_workload_seed: &[Vec<f64>]) -> f64 {
    let per_workload: Vec<f64> = per_workload_seed
        .iter()
        .filter(|seeds| !seeds.is_empty()) // workload filtered out of the grid
        .map(|seeds| stats::mean(seeds))
        .collect();
    stats::mean(&per_workload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regret_zero_at_optimum() {
        assert_eq!(regret(10.0, 10.0), 0.0);
        assert!((regret(15.0, 10.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn savings_signs() {
        // Finding a config 2x cheaper, negligible search cost: ~50%.
        let s = savings(0.0, 5.0, 10.0, 64);
        assert!((s - 0.5).abs() < 1e-12);
        // Exhaustive-search-like: huge search cost -> negative.
        let s2 = savings(10_000.0, 5.0, 10.0, 64);
        assert!(s2 < 0.0);
        // No improvement, nonzero search cost -> negative.
        assert!(savings(1.0, 10.0, 10.0, 64) < 0.0);
    }

    #[test]
    fn savings_improve_with_amortization() {
        let short = savings(100.0, 5.0, 10.0, 4);
        let long = savings(100.0, 5.0, 10.0, 1000);
        assert!(long > short);
    }

    #[test]
    fn aggregation_weights_workloads_equally() {
        // Workload A has 2 seeds, workload B has 4 — B must not dominate.
        let a = vec![vec![1.0, 1.0], vec![0.0, 0.0, 0.0, 0.0]];
        assert!((mean_regret_over_workloads(&a) - 0.5).abs() < 1e-12);
    }
}
