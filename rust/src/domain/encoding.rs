//! Flattened one-hot encoding of the hierarchical domain.
//!
//! This is the feature representation shared by every surrogate — the
//! native Rust GP/RBF/RF and the AOT-compiled PJRT graphs — so its width
//! must equal the `D` baked into `python/compile/model.py` (checked at
//! runtime against the artifact manifest, and by `layout_matches_paper`
//! below).
//!
//! Layout (width 20):
//! ```text
//!   [0..3)   provider one-hot (aws, azure, gcp)
//!   [3]      nodes, min-max normalized to [0, 1]
//!   [4..9)   aws:   family(m4,r4,c4) + size(large,xlarge)
//!   [9..13)  azure: family(D_v2,D_v3) + cpu_size(2,4)
//!   [13..20) gcp:   family(e2,n1) + type(std,hm,hc) + vcpu(2,4)
//! ```
//! Hierarchical (per-provider) optimizers reuse the same encoding with
//! foreign provider blocks left at zero, which is exactly what this
//! function produces — one artifact serves every optimizer.

use super::{Config, Domain};

/// Must equal `model.D` on the python side.
pub const ENCODED_DIM: usize = 20;

/// Encode a configuration into the shared feature vector.
pub fn encode(domain: &Domain, cfg: &Config) -> Vec<f64> {
    let k = domain.providers.len();
    let width = feature_width(domain);
    let mut x = vec![0.0; width];
    x[cfg.provider] = 1.0;

    let n_lo = *domain.nodes.first().expect("empty nodes") as f64;
    let n_hi = *domain.nodes.last().expect("empty nodes") as f64;
    x[k] = if n_hi > n_lo { (cfg.nodes as f64 - n_lo) / (n_hi - n_lo) } else { 0.0 };

    // Offset of this provider's categorical block.
    let mut off = k + 1;
    for p in 0..cfg.provider {
        off += domain.providers[p].params.iter().map(|q| q.values.len()).sum::<usize>();
    }
    for (q, &choice) in domain.providers[cfg.provider].params.iter().zip(&cfg.choices) {
        x[off + choice] = 1.0;
        off += q.values.len();
    }
    x
}

/// Total encoded width for an arbitrary domain (providers + nodes + all
/// categorical values).
pub fn feature_width(domain: &Domain) -> usize {
    domain.providers.len()
        + 1
        + domain
            .providers
            .iter()
            .map(|p| p.params.iter().map(|q| q.values.len()).sum::<usize>())
            .sum::<usize>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::Domain;

    #[test]
    fn layout_matches_paper() {
        let d = Domain::paper();
        assert_eq!(feature_width(&d), ENCODED_DIM);
    }

    #[test]
    fn one_hot_blocks_sum_correctly() {
        let d = Domain::paper();
        for cfg in d.full_grid() {
            let x = encode(&d, &cfg);
            assert_eq!(x.len(), ENCODED_DIM);
            // Provider one-hot sums to 1.
            assert_eq!(x[..3].iter().sum::<f64>(), 1.0);
            // Exactly (number of params of the chosen provider) categorical
            // ones beyond provider + nodes dims.
            let cat_ones: f64 = x[4..].iter().sum();
            assert_eq!(cat_ones, d.providers[cfg.provider].params.len() as f64);
            // Nodes dim within [0,1].
            assert!((0.0..=1.0).contains(&x[3]));
        }
    }

    #[test]
    fn encoding_is_injective_on_paper_grid() {
        let d = Domain::paper();
        let mut seen = std::collections::HashSet::new();
        for cfg in d.full_grid() {
            let x = encode(&d, &cfg);
            let key: Vec<u64> = x.iter().map(|v| v.to_bits()).collect();
            assert!(seen.insert(key), "duplicate encoding for {}", cfg.label(&d));
        }
    }

    #[test]
    fn foreign_provider_blocks_are_zero() {
        let d = Domain::paper();
        let cfg = crate::domain::Config { provider: 1, choices: vec![1, 0], nodes: 5 };
        let x = encode(&d, &cfg);
        // AWS block [4..9) and GCP block [13..20) must be zero.
        assert!(x[4..9].iter().all(|&v| v == 0.0));
        assert!(x[13..20].iter().all(|&v| v == 0.0));
        // Azure block has exactly two ones.
        assert_eq!(x[9..13].iter().sum::<f64>(), 2.0);
    }

    #[test]
    fn nodes_normalization_spans_unit_interval() {
        let d = Domain::paper();
        let mk = |n| crate::domain::Config { provider: 0, choices: vec![0, 0], nodes: n };
        assert_eq!(encode(&d, &mk(2))[3], 0.0);
        assert_eq!(encode(&d, &mk(5))[3], 1.0);
        assert!((encode(&d, &mk(3))[3] - 1.0 / 3.0).abs() < 1e-12);
    }
}
