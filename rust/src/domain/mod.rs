//! The hierarchical multi-cloud configuration domain (paper §III-A).
//!
//! Each cloud provider exposes its own categorical parameters (Table II);
//! the cluster size (`nodes`) is shared. The domain supports three views:
//!
//! * **hierarchical** — per-provider grids, used by the `x3` adaptation,
//!   SMAC-lite / HyperOpt-lite conditional sampling, Rising Bandits and
//!   CloudBandit arms;
//! * **flattened**    — one joint grid over all providers, used by the
//!   `x1` adaptation, random and exhaustive search;
//! * **encoded**      — a fixed-width one-hot feature vector (width
//!   [`ENCODED_DIM`] = the AOT artifacts' `D`), shared by every surrogate
//!   (native and PJRT-backed) so one compiled executable serves them all.

pub mod encoding;

pub use encoding::{encode, ENCODED_DIM};

/// One categorical parameter of a provider (e.g. AWS `family`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamDef {
    pub name: &'static str,
    pub values: Vec<&'static str>,
}

/// A provider's configuration space: the cross product of its parameters.
#[derive(Clone, Debug)]
pub struct ProviderSpace {
    pub name: &'static str,
    pub params: Vec<ParamDef>,
}

impl ProviderSpace {
    /// Number of parameter combinations (excluding the nodes axis).
    pub fn type_count(&self) -> usize {
        self.params.iter().map(|p| p.values.len()).product()
    }

    /// Decode a flat type index into per-parameter value indices
    /// (mixed-radix, first parameter most significant).
    pub fn decode_type(&self, mut idx: usize) -> Vec<usize> {
        let mut out = vec![0; self.params.len()];
        for (i, p) in self.params.iter().enumerate().rev() {
            out[i] = idx % p.values.len();
            idx /= p.values.len();
        }
        out
    }

    pub fn encode_type(&self, choices: &[usize]) -> usize {
        assert_eq!(choices.len(), self.params.len());
        let mut idx = 0;
        for (p, &c) in self.params.iter().zip(choices) {
            assert!(c < p.values.len(), "choice out of range");
            idx = idx * p.values.len() + c;
        }
        idx
    }
}

/// The full multi-cloud domain.
#[derive(Clone, Debug)]
pub struct Domain {
    pub providers: Vec<ProviderSpace>,
    pub nodes: Vec<u32>,
}

/// One point of the domain: a provider, its parameter choices, and the
/// cluster size.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Config {
    pub provider: usize,
    /// Per-parameter value indices into the provider's `params`.
    pub choices: Vec<usize>,
    pub nodes: u32,
}

impl Config {
    /// Human-readable name, e.g. `aws/family=m4/size=xlarge/nodes=4`.
    pub fn label(&self, domain: &Domain) -> String {
        let p = &domain.providers[self.provider];
        let mut s = p.name.to_string();
        for (def, &c) in p.params.iter().zip(&self.choices) {
            s.push_str(&format!("/{}={}", def.name, def.values[c]));
        }
        s.push_str(&format!("/nodes={}", self.nodes));
        s
    }
}

impl Domain {
    /// The paper's exact configuration space (Table II): AWS 24 / Azure 16
    /// / GCP 48 = 88 multi-cloud configurations, nodes in 2..=5.
    pub fn paper() -> Domain {
        Domain {
            providers: vec![
                ProviderSpace {
                    name: "aws",
                    params: vec![
                        ParamDef { name: "family", values: vec!["m4", "r4", "c4"] },
                        ParamDef { name: "size", values: vec!["large", "xlarge"] },
                    ],
                },
                ProviderSpace {
                    name: "azure",
                    params: vec![
                        ParamDef { name: "family", values: vec!["D_v2", "D_v3"] },
                        ParamDef { name: "cpu_size", values: vec!["2", "4"] },
                    ],
                },
                ProviderSpace {
                    name: "gcp",
                    params: vec![
                        ParamDef { name: "family", values: vec!["e2", "n1"] },
                        ParamDef {
                            name: "type",
                            values: vec!["standard", "highmem", "highcpu"],
                        },
                        ParamDef { name: "vcpu", values: vec!["2", "4"] },
                    ],
                },
            ],
            nodes: vec![2, 3, 4, 5],
        }
    }

    pub fn provider_count(&self) -> usize {
        self.providers.len()
    }

    pub fn provider_index(&self, name: &str) -> Option<usize> {
        self.providers.iter().position(|p| p.name == name)
    }

    /// Configurations for one provider (type grid x nodes).
    pub fn provider_grid(&self, provider: usize) -> Vec<Config> {
        let p = &self.providers[provider];
        let mut out = Vec::with_capacity(p.type_count() * self.nodes.len());
        for t in 0..p.type_count() {
            let choices = p.decode_type(t);
            for &n in &self.nodes {
                out.push(Config { provider, choices: choices.clone(), nodes: n });
            }
        }
        out
    }

    /// The full flattened grid across all providers, in stable order
    /// (provider-major). This order defines `config_id`.
    pub fn full_grid(&self) -> Vec<Config> {
        (0..self.providers.len()).flat_map(|p| self.provider_grid(p)).collect()
    }

    /// Stable index of a config in `full_grid` order.
    pub fn config_id(&self, cfg: &Config) -> usize {
        let mut base = 0;
        for p in 0..cfg.provider {
            base += self.providers[p].type_count() * self.nodes.len();
        }
        let t = self.providers[cfg.provider].encode_type(&cfg.choices);
        let n_idx = self
            .nodes
            .iter()
            .position(|&n| n == cfg.nodes)
            .expect("nodes value not in domain");
        base + t * self.nodes.len() + n_idx
    }

    pub fn size(&self) -> usize {
        self.providers
            .iter()
            .map(|p| p.type_count() * self.nodes.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_domain_cardinalities() {
        let d = Domain::paper();
        assert_eq!(d.providers[0].type_count() * d.nodes.len(), 24); // AWS
        assert_eq!(d.providers[1].type_count() * d.nodes.len(), 16); // Azure
        assert_eq!(d.providers[2].type_count() * d.nodes.len(), 48); // GCP
        assert_eq!(d.size(), 88);
        assert_eq!(d.full_grid().len(), 88);
    }

    #[test]
    fn config_ids_are_stable_and_bijective() {
        let d = Domain::paper();
        let grid = d.full_grid();
        for (i, cfg) in grid.iter().enumerate() {
            assert_eq!(d.config_id(cfg), i, "config {}", cfg.label(&d));
        }
    }

    #[test]
    fn type_roundtrip() {
        let d = Domain::paper();
        for p in &d.providers {
            for t in 0..p.type_count() {
                let c = p.decode_type(t);
                assert_eq!(p.encode_type(&c), t);
            }
        }
    }

    #[test]
    fn labels_are_descriptive() {
        let d = Domain::paper();
        let cfg = Config { provider: 2, choices: vec![1, 2, 0], nodes: 3 };
        assert_eq!(cfg.label(&d), "gcp/family=n1/type=highcpu/vcpu=2/nodes=3");
    }

    #[test]
    fn provider_grids_partition_full_grid() {
        let d = Domain::paper();
        let total: usize = (0..3).map(|p| d.provider_grid(p).len()).sum();
        assert_eq!(total, d.size());
        // No overlap: every config's provider matches its grid.
        for p in 0..3 {
            assert!(d.provider_grid(p).iter().all(|c| c.provider == p));
        }
    }

    #[test]
    fn property_config_id_bijection_random_domains() {
        crate::testkit::check("config_id bijection", 30, |g| {
            let providers = (0..g.usize_in(1, 4))
                .map(|pi| ProviderSpace {
                    name: ["p0", "p1", "p2", "p3"][pi],
                    params: (0..g.usize_in(1, 3))
                        .map(|qi| ParamDef {
                            name: ["a", "b", "c"][qi],
                            values: vec!["x", "y", "z"][..g.usize_in(1, 3)].to_vec(),
                        })
                        .collect(),
                })
                .collect::<Vec<_>>();
            let d = Domain { providers, nodes: vec![2, 3, 4] };
            let grid = d.full_grid();
            assert_eq!(grid.len(), d.size());
            for (i, cfg) in grid.iter().enumerate() {
                assert_eq!(d.config_id(cfg), i);
            }
        });
    }
}
