//! Multi-cloud performance simulator (DESIGN.md §Substitutions).
//!
//! The paper measured 30 real Dask workloads on real AWS/Azure/GCP
//! Kubernetes clusters; that substrate is not available here, so this
//! module generates an equivalent offline benchmark dataset from a
//! generative performance model:
//!
//! ```text
//! t(n, machine) = affinity(provider, task) * noise *
//!   [ serial/speed
//!   + parallel_vcpu_s*scale / (speed * n*vcpus) * mem_penalty
//!   + net_factor * (comm_log*scale*log2(n) + comm_a2a*scale*(n-1))
//!   + per_node_overhead * n ]
//! cost = t/3600 * price_per_node_hour * n        (same estimate as §IV-A)
//! ```
//!
//! The Ernest-style scaling law, the memory-pressure penalty (spilling
//! when the per-node shard exceeds usable RAM) and the provider traits
//! together produce the structure the paper's findings rely on: providers
//! differ systematically, response surfaces are smooth in nodes but
//! discontinuous across categories, and cost/runtime optima disagree.

pub mod machines;
pub mod market;
pub mod tasks;

use crate::domain::{Config, Domain};
use crate::util::rng::Rng;
use machines::{machine_spec, provider_traits, MachineSpec};
use tasks::Workload;

/// Fraction of node memory usable by the workload (rest: OS/k8s overhead).
const USABLE_MEM_FRACTION: f64 = 0.75;

/// Deterministic per-(provider, task) affinity in [0.9, 1.1]: models
/// systematic differences (CPU generations, hypervisor, storage) that are
/// not captured by the catalog, so no provider dominates uniformly.
pub fn affinity(provider_name: &str, task_name: &str) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in provider_name.bytes().chain([b'/']).chain(task_name.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut s = h;
    let u = crate::util::rng::splitmix64(&mut s) as f64 / u64::MAX as f64;
    0.9 + 0.2 * u
}

/// The deterministic (noise-free) runtime model in seconds.
pub fn expected_runtime_s(domain: &Domain, w: &Workload, cfg: &Config) -> f64 {
    let m: MachineSpec = machine_spec(domain, cfg);
    let p = &domain.providers[cfg.provider];
    let traits = provider_traits(p.name);
    let n = cfg.nodes as f64;
    let t = &w.task;
    let scale = w.dataset.scale;

    // Memory pressure: per-node shard vs usable RAM; quadratic spill
    // penalty once the shard no longer fits.
    let mem_req_gb = w.dataset.size_gb * t.mem_factor;
    let shard = mem_req_gb / n;
    let usable = m.mem_gb * USABLE_MEM_FRACTION;
    let pressure = shard / usable;
    let mem_penalty = if pressure > 1.0 {
        // Quadratic spill penalty, saturating at 24x: once the working
        // set no longer fits, pages go to disk and throughput collapses
        // by an order of magnitude or more (this wide spread between the
        // best and the pathological configurations is what the paper's
        // real cloud measurements exhibit, and what makes random-choice
        // deployment expensive — §IV-E).
        (1.0 + 3.0 * (pressure - 1.0) + 2.0 * (pressure - 1.0).powi(2)).min(24.0)
    } else {
        1.0
    };

    let serial = t.serial_s / m.speed;
    let parallel =
        t.parallel_vcpu_s * scale / (m.speed * n * m.vcpus as f64) * mem_penalty;
    let comm = traits.net_factor
        * scale
        * (t.comm_log_s * n.log2() + t.comm_a2a_s * (n - 1.0));
    let overhead = traits.per_node_overhead_s * n;

    affinity(p.name, t.name) * (serial + parallel + comm + overhead)
}

/// One simulated measurement: (runtime seconds, cost USD), with
/// multiplicative log-normal noise drawn from `rng`.
pub fn measure(domain: &Domain, w: &Workload, cfg: &Config, rng: &mut Rng) -> (f64, f64) {
    let t = expected_runtime_s(domain, w, cfg) * rng.lognormal_factor(w.task.noise_sigma);
    let m = machine_spec(domain, cfg);
    // Same cost estimate as the paper: runtime x node price x node count.
    let cost = t / 3600.0 * m.price_per_hour * cfg.nodes as f64;
    (t, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::tasks::all_workloads;

    fn cfg(provider: usize, choices: Vec<usize>, nodes: u32) -> Config {
        Config { provider, choices, nodes }
    }

    #[test]
    fn runtimes_are_positive_and_sane() {
        let d = Domain::paper();
        for w in all_workloads() {
            for c in d.full_grid() {
                let t = expected_runtime_s(&d, &w, &c);
                // Worst case: a big-memory workload on 2 lean slow nodes,
                // fully thrashing (24x spill penalty) — ~2 days. Anything
                // beyond ~1 week would indicate a model bug.
                assert!(t.is_finite() && t > 5.0 && t < 500_000.0, "{} on {} -> {t}", w.id(), c.label(&d));
            }
        }
    }

    #[test]
    fn more_nodes_speed_up_compute_bound_tasks() {
        let d = Domain::paper();
        let w = tasks::workload_by_id("kmeans:santander").unwrap();
        let t2 = expected_runtime_s(&d, &w, &cfg(0, vec![0, 1], 2));
        let t5 = expected_runtime_s(&d, &w, &cfg(0, vec![0, 1], 5));
        assert!(t5 < t2, "kmeans should scale: {t2} -> {t5}");
    }

    #[test]
    fn shuffle_heavy_tasks_stop_scaling() {
        // quantile_transformer on the small dataset: all-to-all term grows
        // with n while compute shrinks -> 5 nodes slower than 2.
        let d = Domain::paper();
        let w = tasks::workload_by_id("quantile_transformer:buzz").unwrap();
        let t2 = expected_runtime_s(&d, &w, &cfg(0, vec![0, 1], 2));
        let t5 = expected_runtime_s(&d, &w, &cfg(0, vec![0, 1], 5));
        assert!(t5 > t2, "shuffle-bound should anti-scale: {t2} -> {t5}");
    }

    #[test]
    fn memory_pressure_penalizes_lean_machines() {
        let d = Domain::paper();
        // polynomial_features on buzz needs 35 GB.
        let w = tasks::workload_by_id("polynomial_features:buzz").unwrap();
        // gcp n1 highcpu 2vcpu = 2 GB/node vs n1 highmem 2vcpu = 16 GB/node.
        let lean = expected_runtime_s(&d, &w, &cfg(2, vec![1, 2, 0], 2));
        let fat = expected_runtime_s(&d, &w, &cfg(2, vec![1, 1, 0], 2));
        assert!(lean > 2.0 * fat, "lean {lean} vs fat {fat}");
    }

    #[test]
    fn affinity_is_deterministic_and_bounded() {
        for p in ["aws", "azure", "gcp"] {
            for t in tasks::TASKS {
                let a = affinity(p, t.name);
                assert_eq!(a, affinity(p, t.name));
                assert!((0.9..=1.1).contains(&a));
            }
        }
        assert_ne!(affinity("aws", "kmeans"), affinity("gcp", "kmeans"));
    }

    #[test]
    fn measurement_noise_is_small_and_seeded() {
        let d = Domain::paper();
        let w = tasks::workload_by_id("xgboost:santander").unwrap();
        let c = cfg(1, vec![1, 1], 3);
        let base = expected_runtime_s(&d, &w, &c);
        let (t1, cost1) = measure(&d, &w, &c, &mut Rng::new(1));
        let (t2, _) = measure(&d, &w, &c, &mut Rng::new(1));
        assert_eq!(t1, t2, "same seed, same measurement");
        assert!((t1 / base - 1.0).abs() < 0.5);
        assert!(cost1 > 0.0);
    }

    #[test]
    fn cost_time_tradeoff_exists() {
        // The config minimizing time should differ from the one minimizing
        // cost for at least some workloads — otherwise the paper's two
        // optimization targets collapse.
        let d = Domain::paper();
        let grid = d.full_grid();
        let mut differs = 0;
        for w in all_workloads() {
            let mut best_t = (f64::INFINITY, 0);
            let mut best_c = (f64::INFINITY, 0);
            for (i, c) in grid.iter().enumerate() {
                let t = expected_runtime_s(&d, &w, c);
                let m = machine_spec(&d, c);
                let cost = t / 3600.0 * m.price_per_hour * c.nodes as f64;
                if t < best_t.0 {
                    best_t = (t, i);
                }
                if cost < best_c.0 {
                    best_c = (cost, i);
                }
            }
            if best_t.1 != best_c.1 {
                differs += 1;
            }
        }
        assert!(differs >= 20, "only {differs}/30 workloads have distinct optima");
    }

    #[test]
    fn no_provider_dominates_every_workload() {
        let d = Domain::paper();
        let grid = d.full_grid();
        let mut winner_counts = [0usize; 3];
        for w in all_workloads() {
            let best = grid
                .iter()
                .min_by(|a, b| {
                    expected_runtime_s(&d, &w, a)
                        .partial_cmp(&expected_runtime_s(&d, &w, b))
                        .unwrap()
                })
                .unwrap();
            winner_counts[best.provider] += 1;
        }
        let nonzero = winner_counts.iter().filter(|&&c| c > 0).count();
        assert!(nonzero >= 2, "winner distribution {winner_counts:?}");
    }
}
