//! Workload profiles: the 10 Dask tasks x 3 input datasets of Table II.
//!
//! Each task is characterized by an Ernest-style decomposition of where
//! its time goes (serial fraction, parallelizable vCPU-seconds,
//! tree-aggregation and all-to-all communication) plus a memory footprint
//! factor. The numbers are calibrated so that the *qualitative* contrasts
//! the paper describes hold: XGBoost is communication-heavy with branching
//! logic, k-means is compute-bound with minimal communication, quantile
//! transformation is shuffle(all-to-all)-dominated, polynomial features
//! blow up memory, standard scaling is a cheap single pass, etc.

/// Per-task cost model coefficients (at dataset scale 1.0).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskProfile {
    pub name: &'static str,
    /// Non-parallelizable seconds (driver-side work).
    pub serial_s: f64,
    /// Parallelizable work in vCPU-seconds.
    pub parallel_vcpu_s: f64,
    /// Tree-aggregation seconds multiplied by log2(nodes).
    pub comm_log_s: f64,
    /// All-to-all shuffle seconds multiplied by (nodes - 1).
    pub comm_a2a_s: f64,
    /// Working-set memory = mem_factor x dataset.size_gb.
    pub mem_factor: f64,
    /// Log-normal measurement noise sigma.
    pub noise_sigma: f64,
}

/// The 10 Dask tasks of Table II.
pub const TASKS: [TaskProfile; 10] = [
    TaskProfile { name: "kmeans", serial_s: 20.0, parallel_vcpu_s: 9000.0, comm_log_s: 30.0, comm_a2a_s: 2.0, mem_factor: 1.2, noise_sigma: 0.04 },
    TaskProfile { name: "linear_regression", serial_s: 15.0, parallel_vcpu_s: 5000.0, comm_log_s: 20.0, comm_a2a_s: 4.0, mem_factor: 1.0, noise_sigma: 0.04 },
    TaskProfile { name: "logistic_regression", serial_s: 25.0, parallel_vcpu_s: 6500.0, comm_log_s: 40.0, comm_a2a_s: 3.0, mem_factor: 1.0, noise_sigma: 0.05 },
    TaskProfile { name: "naive_bayes", serial_s: 10.0, parallel_vcpu_s: 1800.0, comm_log_s: 10.0, comm_a2a_s: 1.0, mem_factor: 0.8, noise_sigma: 0.05 },
    TaskProfile { name: "poisson_regression", serial_s: 20.0, parallel_vcpu_s: 5500.0, comm_log_s: 35.0, comm_a2a_s: 3.0, mem_factor: 1.0, noise_sigma: 0.05 },
    TaskProfile { name: "polynomial_features", serial_s: 30.0, parallel_vcpu_s: 4000.0, comm_log_s: 8.0, comm_a2a_s: 25.0, mem_factor: 3.5, noise_sigma: 0.06 },
    TaskProfile { name: "spectral_clustering", serial_s: 60.0, parallel_vcpu_s: 14000.0, comm_log_s: 50.0, comm_a2a_s: 40.0, mem_factor: 2.5, noise_sigma: 0.07 },
    TaskProfile { name: "quantile_transformer", serial_s: 15.0, parallel_vcpu_s: 1500.0, comm_log_s: 10.0, comm_a2a_s: 110.0, mem_factor: 1.4, noise_sigma: 0.06 },
    TaskProfile { name: "standard_scaler", serial_s: 8.0, parallel_vcpu_s: 900.0, comm_log_s: 6.0, comm_a2a_s: 1.0, mem_factor: 0.8, noise_sigma: 0.08 },
    TaskProfile { name: "xgboost", serial_s: 40.0, parallel_vcpu_s: 11000.0, comm_log_s: 120.0, comm_a2a_s: 8.0, mem_factor: 1.5, noise_sigma: 0.06 },
];

/// An input dataset (Table II): its compute scale factor and in-memory
/// footprint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Multiplier on compute/communication work relative to scale 1.0.
    pub scale: f64,
    /// Dense in-memory size (GB) before task-specific expansion.
    pub size_gb: f64,
}

/// The 3 public datasets of Table II.
pub const DATASETS: [DatasetProfile; 3] = [
    DatasetProfile { name: "buzz", scale: 1.8, size_gb: 10.0 },
    DatasetProfile { name: "credit_card", scale: 0.35, size_gb: 2.5 },
    DatasetProfile { name: "santander", scale: 1.0, size_gb: 6.0 },
];

/// ML inference serving tasks — the paper's stated future work ("a
/// similar in-depth study for ML inference applications"), implemented as
/// a second workload suite. An "inference workload" is a fixed batch of
/// requests served through a model replica set: mostly embarrassingly
/// parallel (replicas), with a load-balancer aggregation term, negligible
/// all-to-all traffic, and a hard memory floor for model weights (lean
/// nodes cannot even hold large models without heavy paging).
pub const INFERENCE_TASKS: [TaskProfile; 5] = [
    TaskProfile { name: "bert_serving", serial_s: 12.0, parallel_vcpu_s: 7000.0, comm_log_s: 14.0, comm_a2a_s: 0.5, mem_factor: 2.0, noise_sigma: 0.05 },
    TaskProfile { name: "resnet_serving", serial_s: 8.0, parallel_vcpu_s: 5200.0, comm_log_s: 10.0, comm_a2a_s: 0.5, mem_factor: 1.2, noise_sigma: 0.05 },
    TaskProfile { name: "recsys_ranking", serial_s: 15.0, parallel_vcpu_s: 3800.0, comm_log_s: 25.0, comm_a2a_s: 2.0, mem_factor: 2.8, noise_sigma: 0.06 },
    TaskProfile { name: "ner_pipeline", serial_s: 6.0, parallel_vcpu_s: 2400.0, comm_log_s: 8.0, comm_a2a_s: 0.5, mem_factor: 0.9, noise_sigma: 0.07 },
    TaskProfile { name: "tts_batch", serial_s: 10.0, parallel_vcpu_s: 9500.0, comm_log_s: 12.0, comm_a2a_s: 1.0, mem_factor: 1.6, noise_sigma: 0.05 },
];

/// Request-trace "datasets" for the inference suite: a trace's scale is
/// its request volume; its size is the model + feature footprint.
pub const INFERENCE_TRACES: [DatasetProfile; 2] = [
    DatasetProfile { name: "peak_trace", scale: 1.6, size_gb: 8.0 },
    DatasetProfile { name: "offpeak_trace", scale: 0.5, size_gb: 8.0 },
];

/// A workload = (task, dataset) pair; 30 in total.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Workload {
    pub task: TaskProfile,
    pub dataset: DatasetProfile,
}

impl Workload {
    pub fn id(&self) -> String {
        format!("{}:{}", self.task.name, self.dataset.name)
    }
}

/// All 30 workloads in stable (task-major) order.
pub fn all_workloads() -> Vec<Workload> {
    TASKS
        .iter()
        .flat_map(|&task| DATASETS.iter().map(move |&dataset| Workload { task, dataset }))
        .collect()
}

/// The 10 inference workloads (5 serving tasks x 2 request traces).
pub fn inference_workloads() -> Vec<Workload> {
    INFERENCE_TASKS
        .iter()
        .flat_map(|&task| {
            INFERENCE_TRACES.iter().map(move |&dataset| Workload { task, dataset })
        })
        .collect()
}

pub fn workload_by_id(id: &str) -> Option<Workload> {
    all_workloads()
        .into_iter()
        .chain(inference_workloads())
        .find(|w| w.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirty_workloads_with_unique_ids() {
        let ws = all_workloads();
        assert_eq!(ws.len(), 30);
        let mut ids: Vec<String> = ws.iter().map(|w| w.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 30);
    }

    #[test]
    fn workload_lookup_roundtrip() {
        for w in all_workloads() {
            let got = workload_by_id(&w.id()).unwrap();
            assert_eq!(got.task.name, w.task.name);
            assert_eq!(got.dataset.name, w.dataset.name);
        }
        assert!(workload_by_id("nope:nothing").is_none());
    }

    #[test]
    fn task_contrasts_from_the_paper() {
        let by_name = |n: &str| TASKS.iter().find(|t| t.name == n).unwrap();
        let xgb = by_name("xgboost");
        let km = by_name("kmeans");
        let qt = by_name("quantile_transformer");
        let ss = by_name("standard_scaler");
        let pf = by_name("polynomial_features");
        // XGBoost: complex communication patterns; k-means compute-bound.
        assert!(xgb.comm_log_s > 3.0 * km.comm_log_s);
        assert!(km.parallel_vcpu_s / km.comm_log_s > 100.0);
        // Quantile transform is shuffle-dominated.
        assert!(qt.comm_a2a_s > qt.comm_log_s);
        // Standard scaler is the cheapest task.
        assert!(TASKS.iter().all(|t| t.parallel_vcpu_s >= ss.parallel_vcpu_s));
        // Polynomial features has the largest memory blow-up.
        assert!(TASKS.iter().all(|t| t.mem_factor <= pf.mem_factor));
    }
}
