//! Machine catalog: vCPUs, memory, relative CPU speed and list price for
//! every (provider, parameter combination) in the paper's Table II space.
//!
//! Prices are modelled on 2021 list prices (USD per node-hour) for the
//! regions the paper used; speeds are relative single-thread throughput
//! factors. The absolute values matter less than the *structure* they
//! induce: compute-optimized families are faster but memory-starved,
//! memory-optimized ones are pricier per vCPU, GCP's e2 line is slow but
//! cheap, Azure's D_v2 is an older generation, etc. This is what gives
//! each provider a distinct price/performance profile for the bandit
//! methods to discover.

use crate::domain::{Config, Domain};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachineSpec {
    pub vcpus: u32,
    pub mem_gb: f64,
    /// Relative per-core speed (1.0 = baseline Broadwell-class core).
    pub speed: f64,
    /// USD per node-hour (list price).
    pub price_per_hour: f64,
}

/// Provider-level systematic effects.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProviderTraits {
    /// Multiplier on communication time (inter-node network quality).
    pub net_factor: f64,
    /// Per-node scheduling/provisioning overhead folded into runtime (s).
    pub per_node_overhead_s: f64,
}

pub fn provider_traits(provider_name: &str) -> ProviderTraits {
    match provider_name {
        "aws" => ProviderTraits { net_factor: 1.0, per_node_overhead_s: 2.0 },
        "azure" => ProviderTraits { net_factor: 1.30, per_node_overhead_s: 3.5 },
        "gcp" => ProviderTraits { net_factor: 0.85, per_node_overhead_s: 1.5 },
        other => panic!("unknown provider {other}"),
    }
}

/// Resolve the machine backing a configuration.
pub fn machine_spec(domain: &Domain, cfg: &Config) -> MachineSpec {
    let p = &domain.providers[cfg.provider];
    let val = |param: &str| -> &str {
        let (i, def) = p
            .params
            .iter()
            .enumerate()
            .find(|(_, d)| d.name == param)
            .unwrap_or_else(|| panic!("{} has no param {param}", p.name));
        def.values[cfg.choices[i]]
    };
    match p.name {
        "aws" => {
            let vcpus = match val("size") {
                "large" => 2,
                "xlarge" => 4,
                s => panic!("bad aws size {s}"),
            };
            // (speed, mem per vcpu, price per vcpu-hour)
            let (speed, mem_per, ppv) = match val("family") {
                "m4" => (1.00, 4.0, 0.0500),
                "r4" => (1.02, 8.0, 0.0665),
                "c4" => (1.18, 1.875, 0.0498),
                f => panic!("bad aws family {f}"),
            };
            MachineSpec {
                vcpus,
                mem_gb: mem_per * vcpus as f64,
                speed,
                price_per_hour: ppv * vcpus as f64,
            }
        }
        "azure" => {
            let vcpus: u32 = val("cpu_size").parse().expect("azure cpu_size");
            let (speed, mem_per, ppv) = match val("family") {
                "D_v2" => (0.92, 3.5, 0.0570),
                "D_v3" => (1.06, 4.0, 0.0480),
                f => panic!("bad azure family {f}"),
            };
            MachineSpec {
                vcpus,
                mem_gb: mem_per * vcpus as f64,
                speed,
                price_per_hour: ppv * vcpus as f64,
            }
        }
        "gcp" => {
            let vcpus: u32 = val("vcpu").parse().expect("gcp vcpu");
            let (speed, base_ppv) = match val("family") {
                "e2" => (0.88, 0.0335),
                "n1" => (1.00, 0.0475),
                f => panic!("bad gcp family {f}"),
            };
            let (mem_per, price_mult) = match val("type") {
                "standard" => (4.0, 1.00),
                "highmem" => (8.0, 1.21),
                "highcpu" => (1.0, 0.745),
                t => panic!("bad gcp type {t}"),
            };
            MachineSpec {
                vcpus,
                mem_gb: mem_per * vcpus as f64,
                speed,
                price_per_hour: base_ppv * price_mult * vcpus as f64,
            }
        }
        other => panic!("unknown provider {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::Domain;

    #[test]
    fn every_grid_config_resolves() {
        let d = Domain::paper();
        for cfg in d.full_grid() {
            let m = machine_spec(&d, &cfg);
            assert!(m.vcpus == 2 || m.vcpus == 4, "{}", cfg.label(&d));
            assert!(m.mem_gb > 0.0 && m.speed > 0.5 && m.price_per_hour > 0.0);
        }
    }

    #[test]
    fn aws_c4_is_fast_and_lean() {
        let d = Domain::paper();
        // c4.xlarge vs r4.xlarge
        let c4 = Config { provider: 0, choices: vec![2, 1], nodes: 2 };
        let r4 = Config { provider: 0, choices: vec![1, 1], nodes: 2 };
        let (mc, mr) = (machine_spec(&d, &c4), machine_spec(&d, &r4));
        assert!(mc.speed > mr.speed);
        assert!(mc.mem_gb < mr.mem_gb);
        assert!(mc.price_per_hour < mr.price_per_hour);
    }

    #[test]
    fn gcp_highmem_doubles_memory_for_a_premium() {
        let d = Domain::paper();
        let std = Config { provider: 2, choices: vec![1, 0, 1], nodes: 2 };
        let hm = Config { provider: 2, choices: vec![1, 1, 1], nodes: 2 };
        let (ms, mh) = (machine_spec(&d, &std), machine_spec(&d, &hm));
        assert_eq!(mh.mem_gb, 2.0 * ms.mem_gb);
        assert!(mh.price_per_hour > ms.price_per_hour);
    }

    #[test]
    fn provider_traits_differ() {
        let a = provider_traits("aws");
        let z = provider_traits("azure");
        let g = provider_traits("gcp");
        assert!(g.net_factor < a.net_factor && a.net_factor < z.net_factor);
    }

    #[test]
    #[should_panic(expected = "unknown provider")]
    fn unknown_provider_panics() {
        provider_traits("oracle");
    }
}
