//! Deterministic per-provider market traces: price drift, spot
//! discounts, and seeded revocation events.
//!
//! The paper's dataset is static; real multi-cloud brokering happens in
//! a *dynamic market* (López-Pires et al., PAPERS.md) where prices move
//! and spot capacity is yanked. This module adds the time dimension
//! without adding a clock: market state is a pure function of
//! `(seed, provider, tick)` hashed through the same FNV + SplitMix64
//! idiom as [`crate::simulator::affinity`], so a trace replayed with
//! the same seed is bit-identical — no wall time, no global state.
//!
//! Per `(provider, tick)`:
//! * **price drift** — a smooth sinusoid with a provider-hashed phase
//!   plus small per-tick jitter, normalized so tick 0 is exactly the
//!   catalog price (`price_mult == 1.0`): the online mode's first epoch
//!   scores identically to a static trial;
//! * **spot discount** — with probability [`SPOT_RATE`] the provider
//!   runs a spot window at this tick, discounting the effective price
//!   by a hashed factor in [[`SPOT_DISCOUNT_MIN`], [`SPOT_DISCOUNT_MAX`]];
//! * **revocation** — with probability [`REVOKE_RATE`] the provider's
//!   capacity is revoked at this tick: an incumbent config placed there
//!   must move, and a trial measuring there is *cancelled* (reason
//!   `revoked`), never crashed.

use crate::util::rng::splitmix64;

/// Peak amplitude of the sinusoidal price drift component.
pub const PRICE_DRIFT_AMPLITUDE: f64 = 0.18;
/// Peak amplitude of the per-tick price jitter component.
pub const PRICE_JITTER: f64 = 0.06;
/// Period of the drift sinusoid, in ticks.
pub const PRICE_PERIOD_TICKS: f64 = 16.0;
/// Hard bounds on the price multiplier after drift + jitter.
pub const PRICE_MULT_MIN: f64 = 0.5;
pub const PRICE_MULT_MAX: f64 = 1.5;
/// Probability a provider runs a spot window at a given tick (> 0).
pub const SPOT_RATE: f64 = 0.30;
/// Spot windows discount the effective price into this range.
pub const SPOT_DISCOUNT_MIN: f64 = 0.4;
pub const SPOT_DISCOUNT_MAX: f64 = 0.8;
/// Probability a provider's capacity is revoked at a given tick (> 0).
pub const REVOKE_RATE: f64 = 0.08;

/// Market state of one provider at one logical tick.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MarketState {
    /// Drift + jitter multiplier on the catalog price (1.0 at tick 0).
    pub price_mult: f64,
    /// Spot-window discount factor (1.0 outside a spot window).
    pub spot_discount: f64,
    /// Whether the provider's capacity is revoked at this tick.
    pub revoked: bool,
}

impl MarketState {
    /// Combined multiplier on the catalog price at this tick.
    pub fn effective_price(&self) -> f64 {
        self.price_mult * self.spot_discount
    }
}

/// Uniform in [0, 1), pure in `(seed, provider, tick, salt)`. Same
/// label-hashing idiom as the per-(config, pull) measurement streams in
/// `dataset/objective.rs`: distinct odd multipliers per coordinate, one
/// SplitMix64 finalizer.
fn unit(seed: u64, provider: usize, tick: u64, salt: u64) -> f64 {
    let mut s = seed
        ^ salt
        ^ (provider as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ tick.wrapping_mul(0xD1B5_4A32_D192_ED03);
    splitmix64(&mut s) as f64 / u64::MAX as f64
}

const SALT_PHASE: u64 = 0x6D61_726B_6574_0001;
const SALT_JITTER: u64 = 0x6D61_726B_6574_0002;
const SALT_SPOT: u64 = 0x6D61_726B_6574_0003;
const SALT_SPOT_DEPTH: u64 = 0x6D61_726B_6574_0004;
const SALT_REVOKE: u64 = 0x6D61_726B_6574_0005;

/// The market state of `provider` at logical `tick` under `seed`.
///
/// Tick 0 is always neutral (catalog price, no spot window, no
/// revocation): an online run's first epoch matches the static dataset
/// bit-for-bit, and every divergence after that is market-driven.
pub fn market_state(seed: u64, provider: usize, tick: u64) -> MarketState {
    if tick == 0 {
        return MarketState { price_mult: 1.0, spot_discount: 1.0, revoked: false };
    }
    // Drift is anchored so the sinusoid passes through zero at tick 0;
    // the phase only shapes where in the cycle each provider starts.
    let phase = unit(seed, provider, 0, SALT_PHASE);
    let angle = |t: f64| (t / PRICE_PERIOD_TICKS + phase) * std::f64::consts::TAU;
    let drift = PRICE_DRIFT_AMPLITUDE * (angle(tick as f64).sin() - angle(0.0).sin());
    let jitter = PRICE_JITTER * (2.0 * unit(seed, provider, tick, SALT_JITTER) - 1.0);
    let price_mult = (1.0 + drift + jitter).clamp(PRICE_MULT_MIN, PRICE_MULT_MAX);

    let spot = unit(seed, provider, tick, SALT_SPOT) < SPOT_RATE;
    let spot_discount = if spot {
        SPOT_DISCOUNT_MIN
            + (SPOT_DISCOUNT_MAX - SPOT_DISCOUNT_MIN)
                * unit(seed, provider, tick, SALT_SPOT_DEPTH)
    } else {
        1.0
    };

    let revoked = unit(seed, provider, tick, SALT_REVOKE) < REVOKE_RATE;
    MarketState { price_mult, spot_discount, revoked }
}

/// Combined price multiplier of `provider` at `tick` (drift x spot).
pub fn effective_price(seed: u64, provider: usize, tick: u64) -> f64 {
    market_state(seed, provider, tick).effective_price()
}

/// The providers (indices in `0..providers`) revoked at `tick`, with
/// the guarantee that at least one provider always stays available: a
/// full-market outage would leave an online workload nowhere to run, so
/// if every provider hashes to revoked the highest-index one is kept.
pub fn revoked_providers(seed: u64, providers: usize, tick: u64) -> Vec<usize> {
    let mut out: Vec<usize> =
        (0..providers).filter(|&p| market_state(seed, p, tick).revoked).collect();
    if out.len() == providers && providers > 0 {
        out.pop();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_zero_is_neutral() {
        for seed in [0u64, 1, 42, u64::MAX] {
            for p in 0..3 {
                let s = market_state(seed, p, 0);
                assert_eq!(s, MarketState { price_mult: 1.0, spot_discount: 1.0, revoked: false });
                assert_eq!(s.effective_price(), 1.0);
            }
        }
    }

    #[test]
    fn traces_are_deterministic_and_bounded() {
        for seed in [7u64, 60, 991] {
            for p in 0..3 {
                for tick in 0..200 {
                    let a = market_state(seed, p, tick);
                    let b = market_state(seed, p, tick);
                    assert_eq!(a, b, "seed {seed} provider {p} tick {tick}");
                    assert!((PRICE_MULT_MIN..=PRICE_MULT_MAX).contains(&a.price_mult));
                    assert!(
                        a.spot_discount == 1.0
                            || (SPOT_DISCOUNT_MIN..=SPOT_DISCOUNT_MAX)
                                .contains(&a.spot_discount)
                    );
                    assert!(a.effective_price() > 0.0);
                }
            }
        }
    }

    #[test]
    fn providers_are_decorrelated() {
        // Some tick must separate every provider pair, else the market
        // adds no cross-provider dynamics.
        let differs = (1..50u64).any(|t| {
            let s: Vec<MarketState> = (0..3).map(|p| market_state(60, p, t)).collect();
            s[0] != s[1] && s[1] != s[2] && s[0] != s[2]
        });
        assert!(differs);
    }

    #[test]
    fn spot_windows_and_revocations_occur_at_sane_rates() {
        let mut spots = 0usize;
        let mut revokes = 0usize;
        let n = 3 * 400;
        for p in 0..3 {
            for tick in 1..=400u64 {
                let s = market_state(60, p, tick);
                if s.spot_discount < 1.0 {
                    spots += 1;
                }
                if s.revoked {
                    revokes += 1;
                }
            }
        }
        // Expected: 30% and 8% of 1200 draws; wide tolerance, no flake.
        assert!((n / 6..n / 2).contains(&spots), "{spots} spot windows in {n}");
        assert!((n / 50..n / 4).contains(&revokes), "{revokes} revocations in {n}");
    }

    #[test]
    fn at_least_one_provider_always_survives() {
        for seed in [0u64, 60, 12345] {
            for tick in 0..500 {
                assert!(revoked_providers(seed, 3, tick).len() < 3);
            }
        }
        assert!(revoked_providers(9, 0, 1).is_empty());
    }
}
