//! Property-testing mini-framework (substrate: proptest is unavailable
//! offline — see DESIGN.md §Substitutions).
//!
//! `check` runs a property over many seeded generator instances and, on
//! failure, reports the failing case number and seed so it can be replayed
//! deterministically:
//!
//! ```ignore
//! testkit::check("routing is stable", 200, |g| {
//!     let n = g.usize_in(1, 50);
//!     ...assertions...
//! });
//! ```

use crate::util::rng::Rng;

/// Generator handle passed to properties; wraps a seeded RNG with
/// convenience constructors for common shapes.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// A random subset (possibly empty) of 0..n as sorted indices.
    pub fn subset(&mut self, n: usize) -> Vec<usize> {
        (0..n).filter(|_| self.bool()).collect()
    }
}

/// Environment knob for reproducing a failure: TESTKIT_SEED pins case 0's
/// seed; TESTKIT_CASES overrides the case count.
fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

/// Run `prop` for `cases` generated inputs. Panics (failing the enclosing
/// test) with the case index + replay seed on the first violated property.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base = env_u64("TESTKIT_SEED").unwrap_or(0x5EED_CAFE_F00D_0001);
    let cases = env_u64("TESTKIT_CASES").map(|c| c as usize).unwrap_or(cases);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed) };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with TESTKIT_SEED={base} TESTKIT_CASES={}):\n{msg}",
                case + 1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 50, |g| {
            let x = g.usize_in(0, 10);
            assert!(x <= 10);
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_case_and_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails on large", 100, |g| {
                let x = g.usize_in(0, 100);
                assert!(x < 95, "x = {x}");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("TESTKIT_SEED="), "msg: {msg}");
        assert!(msg.contains("fails on large"), "msg: {msg}");
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first: Vec<usize> = Vec::new();
        check("record", 10, |g| first.push(g.usize_in(0, 1000)));
        let mut second: Vec<usize> = Vec::new();
        check("record", 10, |g| second.push(g.usize_in(0, 1000)));
        assert_eq!(first, second);
    }

    #[test]
    fn subset_is_sorted_and_bounded() {
        check("subset", 50, |g| {
            let s = g.subset(20);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 20));
        });
    }
}
