//! PARIS-style predictive baseline [33], adapted per §IV-B.
//!
//! One random forest per cloud provider predicts the (log) target metric
//! of a workload on a configuration from:
//!   * the configuration's encoded features, and
//!   * a 2-value *fingerprint*: the workload's measured target on two
//!     fixed reference configurations of that provider (the paper's
//!     black-box adaptation — execution values only, no low-level
//!     counters).
//!
//! Offline phase: train on every workload except the target (their stored
//! mean values). Online phase: evaluate the target workload on the 2
//! reference configurations per provider (6 online evaluations total,
//! counted as search expense) and recommend the argmin prediction.

use super::PredictionOutcome;
use crate::dataset::objective::EvalLedger;
use crate::dataset::{OfflineDataset, Target};
use crate::domain::{encode, Config};
use crate::linalg::Matrix;
use crate::surrogate::rf::{RandomForest, RfParams};

/// Indices (within a provider's grid) of the 2 reference configurations.
fn reference_indices(grid_len: usize) -> [usize; 2] {
    [0, grid_len / 2]
}

pub struct ParisPredictor {
    pub n_trees: usize,
}

impl Default for ParisPredictor {
    fn default() -> Self {
        ParisPredictor { n_trees: 40 }
    }
}

impl ParisPredictor {
    pub fn run(
        &self,
        ds: &OfflineDataset,
        workload: usize,
        target: Target,
        ledger: &mut EvalLedger,
    ) -> PredictionOutcome {
        let domain = &ds.domain;
        let mut best: Option<(Config, f64)> = None;
        let mut online_evals = 0;

        for p in 0..domain.provider_count() {
            let grid = domain.provider_grid(p);
            let refs = reference_indices(grid.len());

            // Online fingerprint of the target workload (2 evals, logged
            // through the ledger so the expense is accounted).
            let fp: Vec<f64> = refs
                .iter()
                .map(|&ri| {
                    online_evals += 1;
                    ledger.must_eval(&grid[ri]).max(1e-9).ln()
                })
                .collect();

            // Offline training set: all other workloads. Feature rows
            // (encoding + fingerprint) stream straight into one
            // row-major matrix — the RF fit reads contiguous rows.
            let mut x = Matrix::zeros(0, 0);
            let mut y: Vec<f64> = Vec::new();
            for w in 0..ds.workload_count() {
                if w == workload {
                    continue;
                }
                let train_fp: Vec<f64> = refs
                    .iter()
                    .map(|&ri| {
                        let cid = domain.config_id(&grid[ri]);
                        ds.mean_value(w, cid, target).max(1e-9).ln()
                    })
                    .collect();
                for cfg in &grid {
                    let cid = domain.config_id(cfg);
                    let mut feat = encode(domain, cfg);
                    feat.extend_from_slice(&train_fp);
                    x.push_row(&feat);
                    y.push(ds.mean_value(w, cid, target).max(1e-9).ln());
                }
            }

            let mut rf = RandomForest::new(RfParams {
                n_trees: self.n_trees,
                seed: 0x9A215,
                ..Default::default()
            });
            rf.fit(&x, &y);

            for cfg in &grid {
                let mut feat = encode(domain, cfg);
                feat.extend_from_slice(&fp);
                let (pred, _) = rf.predict_one(&feat);
                if best.as_ref().map(|(_, b)| pred < *b).unwrap_or(true) {
                    best = Some((cfg.clone(), pred));
                }
            }
        }

        PredictionOutcome { chosen: best.expect("providers non-empty").0, online_evals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{LookupObjective, MeasureMode};

    #[test]
    fn reference_indices_distinct() {
        let [a, b] = reference_indices(24);
        assert_ne!(a, b);
        assert!(b < 24);
    }

    #[test]
    fn runs_with_six_online_evals_and_recommends_sanely() {
        let ds = OfflineDataset::generate(19, 3);
        let w = 10;
        let src = LookupObjective::new(&ds, w, Target::Cost, MeasureMode::Mean, 2);
        let mut ledger = EvalLedger::new(&src, 6);
        let out = ParisPredictor::default().run(&ds, w, Target::Cost, &mut ledger);
        assert_eq!(out.online_evals, 6);
        assert_eq!(ledger.evals(), 6);
        drop(ledger);
        let rec = src.ground_truth(&out.chosen);
        // Cross-workload transfer + fingerprint should beat random choice.
        assert!(rec < ds.random_strategy_value(w, Target::Cost), "rec {rec}");
    }
}
