//! Ernest-style linear performance predictor [31], adapted per §IV-B.
//!
//! For every (provider, machine type) the runtime as a function of the
//! cluster size n is modelled with Ernest's features:
//!
//!   t(n) = w0 + w1 * (1/n) + w2 * log2(n) + w3 * n
//!
//! The paper's adaptation trains on *full-dataset* online evaluations in a
//! leave-one-out fashion over cluster sizes: to predict t(n), fit the
//! model on the measurements at all n' != n of the same machine type.
//! The predicted-best configuration is the (type, n) minimizing the LOO
//! prediction. This gives Ernest a best-case treatment (more online data
//! than the original subsampling approach) at a higher online cost — all
//! |grid| evaluations are counted as online expense.

use super::PredictionOutcome;
use crate::dataset::objective::EvalLedger;
use crate::domain::{Config, Domain};
use crate::linalg::{lstsq_ridge, Matrix};

fn features(n: f64) -> Vec<f64> {
    vec![1.0, 1.0 / n, n.log2(), n]
}

/// Fit on (n, y) pairs; predict at n_target. Falls back to the mean when
/// the fit is degenerate.
pub fn loo_predict(train: &[(f64, f64)], n_target: f64) -> f64 {
    let x = Matrix::from_rows(&train.iter().map(|&(n, _)| features(n)).collect::<Vec<_>>());
    let y: Vec<f64> = train.iter().map(|&(_, y)| y).collect();
    match lstsq_ridge(&x, &y, 1e-8) {
        Some(w) => features(n_target).iter().zip(&w).map(|(f, w)| f * w).sum(),
        None => crate::util::stats::mean(&y),
    }
}

pub struct LinearPredictor;

impl LinearPredictor {
    /// Run the predictor for one task: evaluates the full grid online
    /// (through the ledger, so the expense is accounted), then recommends
    /// the configuration with the lowest leave-one-out prediction. The
    /// caller provisions the ledger with at least `domain.size()` budget
    /// (the method has no budget axis — its online cost *is* the grid);
    /// an under-sized ledger panics via `must_eval`.
    pub fn run(&self, domain: &Domain, ledger: &mut EvalLedger) -> PredictionOutcome {
        // Group grid configs by (provider, machine type).
        let grid = domain.full_grid();
        let mut measured: Vec<f64> = Vec::with_capacity(grid.len());
        for cfg in &grid {
            measured.push(ledger.must_eval(cfg));
        }

        let mut best: Option<(usize, f64)> = None;
        for (i, cfg) in grid.iter().enumerate() {
            // Training set: same provider and choices, different nodes.
            let train: Vec<(f64, f64)> = grid
                .iter()
                .enumerate()
                .filter(|(_, c)| {
                    c.provider == cfg.provider && c.choices == cfg.choices && c.nodes != cfg.nodes
                })
                .map(|(j, c)| (c.nodes as f64, measured[j]))
                .collect();
            let pred = loo_predict(&train, cfg.nodes as f64);
            if best.map(|(_, b)| pred < b).unwrap_or(true) {
                best = Some((i, pred));
            }
        }
        let (idx, _) = best.expect("non-empty grid");
        PredictionOutcome { chosen: grid[idx].clone(), online_evals: grid.len() }
    }
}

/// Convenience for tests: recommend using ground-truth means directly.
pub fn recommend_from_means(
    domain: &crate::domain::Domain,
    value_of: impl Fn(&Config) -> f64,
) -> Config {
    let grid = domain.full_grid();
    let measured: Vec<f64> = grid.iter().map(&value_of).collect();
    let mut best: Option<(usize, f64)> = None;
    for (i, cfg) in grid.iter().enumerate() {
        let train: Vec<(f64, f64)> = grid
            .iter()
            .enumerate()
            .filter(|(_, c)| {
                c.provider == cfg.provider && c.choices == cfg.choices && c.nodes != cfg.nodes
            })
            .map(|(j, c)| (c.nodes as f64, measured[j]))
            .collect();
        let pred = loo_predict(&train, cfg.nodes as f64);
        if best.map(|(_, b)| pred < b).unwrap_or(true) {
            best = Some((i, pred));
        }
    }
    grid[best.unwrap().0].clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::objective::{LookupObjective, MeasureMode};
    use crate::dataset::{OfflineDataset, Target};

    #[test]
    fn features_shape() {
        assert_eq!(features(2.0).len(), 4);
    }

    #[test]
    fn loo_recovers_linear_scaling_curves() {
        // y = 10 + 20/n exactly representable -> near-exact LOO prediction.
        let data: Vec<(f64, f64)> =
            [2.0, 3.0, 4.0].iter().map(|&n| (n, 10.0 + 20.0 / n)).collect();
        let pred = loo_predict(&data, 5.0);
        assert!((pred - 14.0).abs() < 0.5, "pred {pred}");
    }

    #[test]
    fn predictor_runs_and_spends_grid_evals() {
        let ds = OfflineDataset::generate(17, 3);
        let src = LookupObjective::new(&ds, 4, Target::Time, MeasureMode::Mean, 1);
        let mut ledger = EvalLedger::new(&src, ds.domain.size());
        let out = LinearPredictor.run(&ds.domain, &mut ledger);
        assert_eq!(out.online_evals, 88);
        assert_eq!(ledger.evals(), 88);
        drop(ledger);
        let _ = ds.domain.config_id(&out.chosen);
        // With full information the recommendation should be decent:
        // better than the random-strategy mean.
        let rec_val = src.ground_truth(&out.chosen);
        assert!(rec_val < ds.random_strategy_value(4, Target::Time));
    }
}
