//! Predictive (model-based) baselines from the cloud-configuration
//! literature (paper §II-A, evaluated in Figure 2 as horizontal lines).
//!
//! * [`ernest::LinearPredictor`] — Ernest-style [31] linear scaling model,
//!   fit per (workload, provider, machine type) with leave-one-out over
//!   cluster sizes.
//! * [`paris::ParisPredictor`]  — PARIS-style [33] random forest trained
//!   offline on *other* workloads, plus an online fingerprint of the
//!   target workload on 2 reference configurations per provider.
//!
//! Unlike search methods these have no budget axis: each returns one
//! predicted-best configuration (plus the online evaluations it had to
//! make, for the savings accounting).

pub mod ernest;
pub mod paris;

use crate::domain::Config;

/// Outcome of a predictive method on one (workload, target) task.
#[derive(Clone, Debug)]
pub struct PredictionOutcome {
    /// Configuration the method recommends.
    pub chosen: Config,
    /// Number of *online* objective evaluations the method performed.
    pub online_evals: usize,
}
