//! Renderers for the paper's tables and figures (DESIGN.md §4 index).
//!
//! Each figure has two outputs: a CSV with the exact numbers (written to
//! the results directory for external plotting / EXPERIMENTS.md) and an
//! ASCII rendering for the terminal.

use crate::coordinator::experiment::RegretCurve;
use crate::coordinator::savings::SavingsDistribution;
use crate::dataset::{OfflineDataset, Target};
use crate::domain::Domain;
use crate::report::{ascii_bars, ascii_box, ascii_table};
use crate::simulator::tasks::{DATASETS, TASKS};
use crate::util::csv;

/// Table I — the state-of-the-art summary (static literature table,
/// reproduced for completeness).
pub fn table1() -> String {
    let header: Vec<String> = ["Paper", "Type", "Algorithms", "Offline", "Online", "Low-level", "Multi-cloud"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let rows: Vec<Vec<&str>> = vec![
        vec!["Ernest [31]", "Predictive", "Linear Regression", "no", "yes", "no", "no"],
        vec!["Mariani+ [25]", "Predictive", "Random Forest", "yes", "no", "yes", "no"],
        vec!["PARIS [33]", "Predictive", "Random Forest", "yes", "yes", "yes", "yes"],
        vec!["Selecta [21]", "Predictive", "Collab. Filtering", "yes", "yes", "no", "no"],
        vec!["CherryPick [1]", "Search", "Bayesian Opt.", "no", "yes", "no", "no"],
        vec!["Bilal+ [3]", "Search", "BO, SHC, SA, TPE", "no", "yes", "no", "no"],
        vec!["Arrow [14]", "Search", "Augmented BO", "no", "yes", "yes", "no"],
        vec!["Scout [16]", "Search", "Pairwise Modelling", "yes", "yes", "yes", "no"],
        vec!["Micky [15]", "Search", "Multi-armed Bandits", "no", "yes", "no", "no"],
        vec!["This repo", "Search", "RBFOpt, HyperOpt, SMAC, CloudBandit", "no", "yes", "no", "yes"],
    ];
    let rows: Vec<Vec<String>> =
        rows.into_iter().map(|r| r.into_iter().map(|s| s.to_string()).collect()).collect();
    ascii_table(&header, &rows)
}

/// Table II — optimization tasks and the configuration space.
pub fn table2(domain: &Domain) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    rows.push(vec![
        "Dask tasks".into(),
        TASKS.iter().map(|t| t.name).collect::<Vec<_>>().join(", "),
    ]);
    rows.push(vec![
        "Datasets".into(),
        DATASETS.iter().map(|d| d.name).collect::<Vec<_>>().join(", "),
    ]);
    rows.push(vec!["Targets".into(), "cost, runtime".into()]);
    for p in &domain.providers {
        let desc = p
            .params
            .iter()
            .map(|q| format!("{}: {}", q.name, q.values.join(", ")))
            .collect::<Vec<_>>()
            .join("; ");
        rows.push(vec![
            format!("{} ({} configs)", p.name, p.type_count() * domain.nodes.len()),
            desc,
        ]);
    }
    rows.push(vec![
        "Nodes".into(),
        domain.nodes.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(", "),
    ]);
    rows.push(vec!["Total configurations".into(), domain.size().to_string()]);
    ascii_table(&["Field".into(), "Values".into()], &rows)
}

/// Regret curves (Figures 2 and 3) as CSV: method,target,budget,regret.
pub fn regret_csv(curves: &[RegretCurve]) -> String {
    let mut rows = vec![vec![
        "method".to_string(),
        "target".to_string(),
        "budget".to_string(),
        "mean_regret".to_string(),
    ]];
    for c in curves {
        for (b, r) in c.budgets.iter().zip(&c.mean_regret) {
            rows.push(vec![
                c.method.clone(),
                c.target.name().to_string(),
                b.to_string(),
                format!("{r:.6}"),
            ]);
        }
    }
    csv::write_rows(&rows)
}

/// ASCII rendering of regret curves: per target, bars at each budget.
pub fn regret_ascii(title: &str, curves: &[RegretCurve], targets: &[Target]) -> String {
    let mut out = format!("== {title} ==\n");
    for target in targets {
        out.push_str(&format!("\n-- target: {} --\n", target.name()));
        let tcurves: Vec<&RegretCurve> =
            curves.iter().filter(|c| c.target == *target).collect();
        if tcurves.is_empty() {
            continue;
        }
        for (bi, b) in tcurves[0].budgets.iter().enumerate() {
            out.push_str(&format!("\nbudget B = {b}\n"));
            let labels: Vec<String> = tcurves.iter().map(|c| c.method.clone()).collect();
            let values: Vec<f64> = tcurves.iter().map(|c| c.mean_regret[bi]).collect();
            out.push_str(&ascii_bars(&labels, &values, 40));
        }
    }
    out
}

/// Savings distributions (Figure 4) as CSV:
/// method,target,workload,savings.
pub fn savings_csv(ds: &OfflineDataset, dists: &[SavingsDistribution]) -> String {
    let mut rows = vec![vec![
        "method".to_string(),
        "target".to_string(),
        "workload".to_string(),
        "savings".to_string(),
    ]];
    for d in dists {
        for (w, s) in d.per_workload.iter().enumerate() {
            rows.push(vec![
                d.method.clone(),
                d.target.name().to_string(),
                ds.workloads[w].id(),
                format!("{s:.6}"),
            ]);
        }
    }
    csv::write_rows(&rows)
}

/// ASCII rendering of Figure 4: one labelled box plot per method.
pub fn savings_ascii(dists: &[SavingsDistribution]) -> String {
    let mut out = String::new();
    let lo = dists
        .iter()
        .flat_map(|d| d.per_workload.iter().copied())
        .fold(f64::INFINITY, f64::min)
        .min(-1.0);
    let hi = dists
        .iter()
        .flat_map(|d| d.per_workload.iter().copied())
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1.0);
    for d in dists {
        let b = d.box_stats();
        out.push_str(&format!(
            "{:<14} [{}] median {:+.1}%  IQR [{:+.1}%, {:+.1}%]\n",
            d.method,
            ascii_box(&b, lo, hi, 61),
            100.0 * b.median,
            100.0 * b.q1,
            100.0 * b.q3,
        ));
    }
    out.push_str(&format!(
        "axis: {:+.0}% .. {:+.0}% (savings vs random provider+config)\n",
        lo * 100.0,
        hi * 100.0
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mentions_all_methods() {
        let t = table1();
        for m in ["CherryPick", "PARIS", "CloudBandit", "Micky"] {
            assert!(t.contains(m), "missing {m}");
        }
    }

    #[test]
    fn table2_reports_88_configs() {
        let t = table2(&Domain::paper());
        assert!(t.contains("Total configurations"));
        assert!(t.contains("88"));
        assert!(t.contains("xgboost"));
        assert!(t.contains("santander"));
    }

    #[test]
    fn regret_csv_shape() {
        let curves = vec![RegretCurve {
            method: "rs".into(),
            target: Target::Cost,
            budgets: vec![11, 22],
            mean_regret: vec![0.5, 0.25],
        }];
        let text = regret_csv(&curves);
        let parsed = csv::Table::parse(&text).unwrap();
        assert_eq!(parsed.rows.len(), 2);
        assert_eq!(parsed.get(&parsed.rows[1], "budget"), Some("22"));
    }

    #[test]
    fn savings_outputs_render() {
        let ds = OfflineDataset::generate(70, 2);
        let d = SavingsDistribution {
            method: "smac".into(),
            target: Target::Cost,
            per_workload: (0..30).map(|i| (i as f64 - 10.0) / 30.0).collect(),
        };
        let csv_text = savings_csv(&ds, &[d.clone()]);
        assert_eq!(csv::Table::parse(&csv_text).unwrap().rows.len(), 30);
        let ascii = savings_ascii(&[d]);
        assert!(ascii.contains("smac"));
        assert!(ascii.contains("median"));
    }
}
