//! Report generation: ASCII tables/charts and CSV data for every table
//! and figure in the paper's evaluation section (see DESIGN.md §4).

pub mod figures;

use crate::util::stats::BoxStats;

/// Render an aligned ASCII table.
pub fn ascii_table(header: &[String], rows: &[Vec<String>]) -> String {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        assert_eq!(r.len(), cols, "ragged table row");
        for (i, cell) in r.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = String::new();
    out.push_str(&fmt_row(header));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(r));
        out.push('\n');
    }
    out
}

/// Render one horizontal ASCII box plot row (for Figure 4).
///
/// `lo`/`hi` bound the axis; width is the number of character cells.
pub fn ascii_box(b: &BoxStats, lo: f64, hi: f64, width: usize) -> String {
    assert!(hi > lo && width >= 10);
    let clamp = |v: f64| v.clamp(lo, hi);
    let cell = |v: f64| -> usize {
        (((clamp(v) - lo) / (hi - lo)) * (width - 1) as f64).round() as usize
    };
    let mut row = vec![' '; width];
    let (wl, q1, med, q3, wh) =
        (cell(b.whisker_lo), cell(b.q1), cell(b.median), cell(b.q3), cell(b.whisker_hi));
    for c in row.iter_mut().take(q1).skip(wl) {
        *c = '-';
    }
    for c in row.iter_mut().take(wh + 1).skip(q3) {
        *c = '-';
    }
    for c in row.iter_mut().take(q3 + 1).skip(q1) {
        *c = '=';
    }
    row[wl] = '|';
    row[wh] = '|';
    row[med] = '#';
    row.into_iter().collect()
}

/// Simple multi-series ASCII chart: one row per (series, budget) value —
/// regret rendered as a bar. Good enough to eyeball orderings in the
/// terminal; the CSVs carry the precise numbers.
pub fn ascii_bars(labels: &[String], values: &[f64], width: usize) -> String {
    let max = values.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(1e-12);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (l, &v) in labels.iter().zip(values) {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!("{:<lw$} | {:<width$} {:.4}\n", l, "█".repeat(n), v));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = ascii_table(
            &["method".into(), "regret".into()],
            &[
                vec!["rs".into(), "0.35".into()],
                vec!["cb-rbfopt".into(), "0.02".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[2].starts_with(" rs "));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        ascii_table(&["a".into()], &[vec!["1".into(), "2".into()]]);
    }

    #[test]
    fn box_render_has_median_inside_box() {
        let b = BoxStats {
            q1: 0.25,
            median: 0.5,
            q3: 0.75,
            whisker_lo: 0.0,
            whisker_hi: 1.0,
            n: 10,
            outliers: 0,
        };
        let s = ascii_box(&b, 0.0, 1.0, 41);
        let med = s.find('#').unwrap();
        let q1 = s.find('=').unwrap();
        let q3 = s.rfind('=').unwrap();
        assert!(q1 <= med && med <= q3, "{s}");
        assert!(s.starts_with('|') && s.trim_end().ends_with('|'));
    }

    #[test]
    fn bars_scale_to_max() {
        let s = ascii_bars(&["a".into(), "b".into()], &[1.0, 0.5], 10);
        let lines: Vec<&str> = s.lines().collect();
        let count = |l: &str| l.matches('█').count();
        assert_eq!(count(lines[0]), 10);
        assert_eq!(count(lines[1]), 5);
    }
}
