//! multicloud — a reproduction of "Search-based Methods for Multi-Cloud
//! Configuration" (Lazuka et al., 2022) as a three-layer Rust + JAX +
//! Pallas system. See DESIGN.md for the architecture and EXPERIMENTS.md
//! for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): hierarchical domain, multi-cloud simulator + offline
//!   dataset, the full optimizer suite incl. CloudBandit, experiment
//!   coordinator, metrics and report generation.
//! * L2/L1 (python/compile): AOT-lowered GP / RBF surrogate graphs with
//!   Pallas Gram kernels, executed by `runtime` via PJRT.

pub mod benchkit;
pub mod dataset;
pub mod domain;
pub mod coordinator;
pub mod linalg;
pub mod metrics;
pub mod optimizers;
pub mod predictors;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod surrogate;
pub mod testkit;
pub mod util;
