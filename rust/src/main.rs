//! multicloud — CLI launcher for the multi-cloud configuration system.
//!
//! Subcommands:
//!   generate-dataset  materialize the offline benchmark dataset (CSV)
//!   optimize          run one optimizer on one (workload, target) task
//!   figures           regenerate the paper's tables/figures (T1/T2/F2/F3/F4)
//!   savings           the §IV-E savings analysis (Figure 4 numbers)
//!   serve             TCP optimization service (line-delimited JSON)
//!   inspect           show a workload's ground-truth response surface
//!
//! Run `multicloud <cmd> --help` for per-command options.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use multicloud::coordinator::experiment::RegretGrid;
use multicloud::coordinator::savings::{savings_analysis, SavingsConfig};
use multicloud::coordinator::service::{Service, Transport};
use multicloud::dataset::{OfflineDataset, Target, BOTH_TARGETS};
use multicloud::optimizers::ALL_OPTIMIZERS;
use multicloud::report::figures;
use multicloud::runtime::{artifact_dir, ArtifactBackend};
use multicloud::surrogate::{Backend, NativeBackend};
use multicloud::util::cli::Command;

const DATASET_SEED: u64 = 2022;
const DATASET_REPS: usize = 5;

/// Figure 2's method set (adapted SOTA + predictors + RS).
const FIG2_METHODS: [&str; 7] = [
    "predict-linear",
    "predict-rf",
    "rs",
    "cherrypick-x1",
    "cherrypick-x3",
    "bilal-x1",
    "bilal-x3",
];

/// Figure 3's method set (hierarchical methods + references).
const FIG3_METHODS: [&str; 8] = [
    "rs",
    "cherrypick-x1",
    "cherrypick-x3",
    "smac",
    "hyperopt",
    "rb",
    "cb-cherrypick",
    "cb-rbfopt",
];

/// Figure 4's method set.
const FIG4_METHODS: [&str; 4] = ["smac", "cb-rbfopt", "rs", "exhaustive"];

fn load_backend(native: bool, artifacts: &str) -> Box<dyn Backend + Send + Sync> {
    if native {
        eprintln!("[backend] native Rust surrogates (--native)");
        return Box::new(NativeBackend);
    }
    match ArtifactBackend::load(artifacts) {
        Ok(b) => {
            eprintln!(
                "[backend] PJRT artifacts from '{artifacts}' (N={}, M={}, D={})",
                b.manifest.n_max, b.manifest.m_max, b.manifest.d
            );
            Box::new(b)
        }
        Err(e) => {
            eprintln!("[backend] artifacts unavailable ({e}); falling back to native");
            Box::new(NativeBackend)
        }
    }
}

fn load_dataset(path: &str) -> OfflineDataset {
    if path.is_empty() {
        OfflineDataset::generate(DATASET_SEED, DATASET_REPS)
    } else {
        OfflineDataset::load_or_generate(path, DATASET_SEED, DATASET_REPS)
            .unwrap_or_else(|e| fail(&format!("loading dataset {path}: {e}")))
    }
}

fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        eprintln!("{}", usage());
        std::process::exit(2);
    };
    let rest = &argv[1..];
    let code = match cmd {
        "generate-dataset" => cmd_generate(rest),
        "optimize" => cmd_optimize(rest),
        "experiment" => cmd_experiment(rest),
        "figures" => cmd_figures(rest),
        "savings" => cmd_savings(rest),
        "serve" => cmd_serve(rest),
        "inspect" => cmd_inspect(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "multicloud — search-based multi-cloud configuration (paper reproduction)\n\
     \n\
     commands:\n\
       generate-dataset   materialize the offline benchmark dataset\n\
       optimize           run one optimizer on one task\n\
       experiment         run a declarative experiment spec (JSON)\n\
       figures            regenerate Table I/II, Figures 2/3/4\n\
       savings            production savings analysis (Fig. 4 numbers)\n\
       serve              TCP optimization service\n\
       inspect            ground-truth surface of one workload\n"
        .to_string()
}

fn parse_or_exit(c: Command, args: &[String]) -> multicloud::util::cli::Args {
    match c.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn cmd_generate(args: &[String]) -> i32 {
    let c = Command::new("generate-dataset", "materialize the offline benchmark dataset")
        .opt("out", "data/offline.csv", "output CSV path")
        .opt("seed", "2022", "simulator seed")
        .opt("reps", "5", "measurement repetitions per configuration");
    let a = parse_or_exit(c, args);
    let ds = OfflineDataset::generate(a.u64("seed").unwrap(), a.usize("reps").unwrap());
    let out = a.get("out");
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    std::fs::write(out, ds.to_csv()).unwrap_or_else(|e| fail(&e.to_string()));
    println!(
        "wrote {} ({} workloads x {} configs x {} reps)",
        out,
        ds.workload_count(),
        ds.domain.size(),
        ds.reps
    );
    0
}

fn cmd_optimize(args: &[String]) -> i32 {
    let c = Command::new("optimize", "run one optimizer on one (workload, target) task")
        .opt("workload", "kmeans:santander", "workload id (task:dataset)")
        .opt("target", "cost", "optimization target: cost | time")
        .opt("method", "cb-rbfopt", "optimizer name")
        .opt("budget", "33", "search budget (evaluations)")
        .opt("seed", "0", "random seed")
        .opt("trial-workers", "0", "parallel arm workers (0 = all cores; results identical)")
        .opt("measure-mode", "single_draw", "evaluation aggregation: single_draw | mean | p90")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)")
        .opt("artifacts", "", "artifact directory (default: ./artifacts)")
        .flag("native", "use native surrogates instead of PJRT artifacts");
    let a = parse_or_exit(c, args);
    let ds = load_dataset(a.get("dataset"));
    let art = a.get("artifacts");
    let backend =
        load_backend(a.flag("native"), &artifact_dir(Some(art).filter(|s| !s.is_empty())));
    let workload = ds
        .workload_index(a.get("workload"))
        .unwrap_or_else(|| fail(&format!("unknown workload '{}'", a.get("workload"))));
    let target = Target::parse(a.get("target")).unwrap_or_else(|| fail("bad target"));
    let method = a.get("method").to_string();
    if !ALL_OPTIMIZERS.contains(&method.as_str())
        && !multicloud::coordinator::experiment::PREDICTORS.contains(&method.as_str())
    {
        fail(&format!("unknown method '{method}'"));
    }

    let measure_mode = multicloud::dataset::objective::MeasureMode::parse(a.get("measure-mode"))
        .unwrap_or_else(|| fail("bad measure-mode (single_draw | mean | p90)"));
    let max_workers = multicloud::coordinator::spec::MAX_TRIAL_WORKERS;
    let trial_workers = match a.usize("trial-workers").unwrap() {
        // A lone trial owns the whole machine by default.
        0 => multicloud::util::threadpool::default_workers().clamp(1, max_workers),
        w if w <= max_workers => w,
        _ => fail(&format!("trial-workers must be in 0..={max_workers} (0 = adaptive)")),
    };
    let spec = multicloud::coordinator::experiment::TrialSpec {
        method,
        workload,
        target,
        budget: a.usize("budget").unwrap(),
        seed: a.u64("seed").unwrap(),
        trial_workers,
        measure_mode,
    };
    let r = multicloud::coordinator::experiment::run_trial(&ds, backend.as_ref(), &spec);
    let (_, true_min) = ds.true_min(workload, target);
    println!("workload        : {}", a.get("workload"));
    println!("target          : {}", target.name());
    println!("method          : {}", spec.method);
    println!("budget          : {}", spec.budget);
    println!("evaluations     : {}", r.evals);
    println!("chosen value    : {:.4}", r.chosen_value);
    println!("true optimum    : {true_min:.4}");
    println!("regret          : {:.4}", r.regret);
    println!("search expense  : {:.4}", r.search_expense);
    0
}

fn cmd_experiment(args: &[String]) -> i32 {
    let c = Command::new("experiment", "run a declarative experiment spec (JSON)")
        .req("spec", "path to the experiment spec JSON (see coordinator::spec)")
        .opt("out", "results", "output directory")
        .opt("workers", "0", "worker threads (0 = all cores)")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)")
        .flag("native", "use native surrogates instead of PJRT artifacts");
    let a = parse_or_exit(c, args);
    let spec = multicloud::coordinator::spec::ExperimentSpec::load(a.get("spec"))
        .unwrap_or_else(|e| fail(&e));
    let ds = load_dataset(a.get("dataset"));
    let backend = load_backend(a.flag("native"), &artifact_dir(None));

    let workload_filter: Vec<usize> = spec
        .workloads
        .iter()
        .map(|id| ds.workload_index(id).unwrap_or_else(|| fail(&format!("unknown workload '{id}'"))))
        .collect();

    let mut grid = RegretGrid::new(&ds, backend.as_ref());
    grid.methods = spec.methods.clone();
    grid.budgets = spec.budgets.clone();
    grid.seeds = spec.seeds;
    grid.targets = spec.targets.clone();
    grid.workload_filter = workload_filter;
    grid.workers = match a.usize("workers").unwrap() {
        0 => multicloud::util::threadpool::default_workers(),
        w => w,
    };
    grid.trial_workers = spec.trial_workers;
    grid.measure_mode = spec.measure_mode;
    grid.verbose = true;
    grid.online = spec.online;
    let curves = grid.run();

    let ascii = figures::regret_ascii(&spec.name, &curves, &spec.targets);
    println!("{ascii}");
    let out = a.get("out");
    std::fs::create_dir_all(out).ok();
    let csv_path = format!("{out}/{}.csv", spec.name);
    std::fs::write(&csv_path, figures::regret_csv(&curves)).unwrap_or_else(|e| fail(&e.to_string()));
    eprintln!("[experiment] wrote {csv_path}");
    0
}

struct FigureOpts {
    out: String,
    seeds: usize,
    workers: usize,
}

fn write_result(opts: &FigureOpts, name: &str, content: &str) {
    std::fs::create_dir_all(&opts.out).ok();
    let path = format!("{}/{}", opts.out, name);
    std::fs::write(&path, content).unwrap_or_else(|e| fail(&e.to_string()));
    eprintln!("[figures] wrote {path}");
}

fn cmd_figures(args: &[String]) -> i32 {
    let c = Command::new("figures", "regenerate the paper's tables and figures")
        .flag("table1", "Table I (state-of-the-art summary)")
        .flag("table2", "Table II (tasks + configuration space)")
        .flag("fig2", "Figure 2 (adapted SOTA regret)")
        .flag("fig3", "Figure 3 (hierarchical methods regret)")
        .flag("fig4", "Figure 4 (savings box plots)")
        .flag("all", "everything")
        .opt("out", "results", "output directory for CSV/text")
        .opt("seeds", "50", "random repetitions per (method, workload, budget)")
        .opt("budgets", "11,22,33,44,55,66,77,88", "budget grid")
        .opt("workers", "0", "worker threads (0 = all cores)")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)")
        .opt("artifacts", "", "artifact directory")
        .flag("native", "use native surrogates instead of PJRT artifacts");
    let a = parse_or_exit(c, args);
    let all = a.flag("all");
    let ds = load_dataset(a.get("dataset"));
    let art = a.get("artifacts");
    let backend =
        load_backend(a.flag("native"), &artifact_dir(Some(art).filter(|s| !s.is_empty())));
    let budgets: Vec<usize> = a
        .list("budgets")
        .iter()
        .map(|b| b.parse().unwrap_or_else(|_| fail("bad --budgets")))
        .collect();
    let opts = FigureOpts {
        out: a.get("out").to_string(),
        seeds: a.usize("seeds").unwrap(),
        workers: match a.usize("workers").unwrap() {
            0 => multicloud::util::threadpool::default_workers(),
            w => w,
        },
    };

    if all || a.flag("table1") {
        let t = figures::table1();
        println!("{t}");
        write_result(&opts, "table1.txt", &t);
    }
    if all || a.flag("table2") {
        let t = figures::table2(&ds.domain);
        println!("{t}");
        write_result(&opts, "table2.txt", &t);
    }

    let mut run_fig = |name: &str, methods: &[&str]| {
        eprintln!(
            "[figures] running {name} grid ({} methods, {} budgets, {} seeds)...",
            methods.len(),
            budgets.len(),
            opts.seeds
        );
        let started = std::time::Instant::now();
        let mut grid = RegretGrid::new(&ds, backend.as_ref());
        grid.methods = methods.iter().map(|m| m.to_string()).collect();
        grid.budgets = budgets.clone();
        grid.seeds = opts.seeds;
        grid.workers = opts.workers;
        grid.verbose = true;
        let curves = grid.run();
        eprintln!("[figures] {name} done in {:.1}s", started.elapsed().as_secs_f64());
        let ascii = figures::regret_ascii(name, &curves, &BOTH_TARGETS);
        println!("{ascii}");
        write_result(&opts, &format!("{name}.csv"), &figures::regret_csv(&curves));
        write_result(&opts, &format!("{name}.txt"), &ascii);
    };

    if all || a.flag("fig2") {
        run_fig("fig2", &FIG2_METHODS);
    }
    if all || a.flag("fig3") {
        run_fig("fig3", &FIG3_METHODS);
    }
    if all || a.flag("fig4") {
        let cfg = SavingsConfig { seeds: opts.seeds, workers: opts.workers, ..Default::default() };
        let methods: Vec<String> = FIG4_METHODS.iter().map(|m| m.to_string()).collect();
        let mut all_dists = Vec::new();
        for target in BOTH_TARGETS {
            eprintln!("[figures] savings analysis, target {}", target.name());
            let dists = savings_analysis(&ds, backend.as_ref(), &methods, target, &cfg);
            println!(
                "-- Figure 4, target: {} (B={}, N={}) --",
                target.name(),
                cfg.budget,
                cfg.production_runs
            );
            println!("{}", figures::savings_ascii(&dists));
            all_dists.extend(dists);
        }
        write_result(&opts, "fig4.csv", &figures::savings_csv(&ds, &all_dists));
    }
    0
}

fn cmd_savings(args: &[String]) -> i32 {
    let c = Command::new("savings", "production savings analysis (§IV-E)")
        .opt("methods", "smac,cb-rbfopt,rs,exhaustive", "comma-separated methods")
        .opt("target", "cost", "cost | time")
        .opt("budget", "33", "search budget B")
        .opt("runs", "64", "production runs N")
        .opt("seeds", "50", "random repetitions")
        .opt("workers", "0", "worker threads (0 = all cores)")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)")
        .flag("native", "use native surrogates");
    let a = parse_or_exit(c, args);
    let ds = load_dataset(a.get("dataset"));
    let backend = load_backend(a.flag("native"), &artifact_dir(None));
    let target = Target::parse(a.get("target")).unwrap_or_else(|| fail("bad target"));
    let cfg = SavingsConfig {
        budget: a.usize("budget").unwrap(),
        production_runs: a.usize("runs").unwrap(),
        seeds: a.usize("seeds").unwrap(),
        workers: match a.usize("workers").unwrap() {
            0 => multicloud::util::threadpool::default_workers(),
            w => w,
        },
    };
    let methods = a.list("methods");
    let dists = savings_analysis(&ds, backend.as_ref(), &methods, target, &cfg);
    println!(
        "savings vs random provider+configuration (target {}, B={}, N={}):\n",
        target.name(),
        cfg.budget,
        cfg.production_runs
    );
    println!("{}", figures::savings_ascii(&dists));
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let c = Command::new("serve", "TCP optimization service (line-delimited JSON)")
        .opt("addr", "127.0.0.1:7077", "bind address")
        .opt("conn-workers", "0", "connection worker pool size (0 = auto)")
        .opt(
            "transport",
            "auto",
            "serving transport: epoll (O(ready) readiness, Linux) | poll (portable readiness) \
             | threaded (thread per connection) | auto (best available)",
        )
        .opt(
            "event-loop",
            "auto",
            "legacy transport switch, superseded by --transport: on (readiness loop) | \
             off (thread per connection) | auto",
        )
        .opt(
            "reactors",
            "0",
            "reactor (event-loop) threads for the readiness transports (0 = adaptive: \
             min(cores, 4))",
        )
        .opt("cache-shards", "0", "response cache stripes (0 = default 8, capped by cache-cap)")
        .opt("max-conns", "0", "open-connection cap, clamped to the fd rlimit (0 = default 4096)")
        .opt("idle-timeout", "0", "reap idle connections after this many seconds (0 = default 300)")
        .opt("max-wbuf", "0", "per-connection unflushed response byte cap (0 = default 1 MiB)")
        .opt("max-pending", "0", "per-connection pipelined frame cap (0 = default 64)")
        .opt("shutdown-drain", "-1", "post-stop drain seconds (-1 = default 5)")
        .opt(
            "default-deadline",
            "0",
            "deadline in milliseconds applied to optimize requests that set no deadline_ms \
             of their own (0 = unlimited)",
        )
        .opt("cache-cap", "0", "response cache entries (0 = default)")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)")
        .flag("native", "use native surrogates");
    let a = parse_or_exit(c, args);
    let ds = Arc::new(load_dataset(a.get("dataset")));
    let backend: Arc<dyn Backend + Send + Sync> =
        Arc::from(load_backend(a.flag("native"), &artifact_dir(None)));
    let mut svc = Service::new(ds, backend);
    let conn_workers = a.usize("conn-workers").unwrap_or_else(|e| fail(&e));
    if conn_workers > 0 {
        svc = svc.with_conn_workers(conn_workers);
    }
    let cache_cap = a.usize("cache-cap").unwrap_or_else(|e| fail(&e));
    if cache_cap > 0 {
        svc = svc.with_cache_cap(cache_cap);
    }
    let cache_shards = a.usize("cache-shards").unwrap_or_else(|e| fail(&e));
    if cache_shards > 0 {
        svc = svc.with_cache_shards(cache_shards);
    }
    // 0 = adaptive is the builder's own default, so pass it through.
    let reactors = a.usize("reactors").unwrap_or_else(|e| fail(&e));
    svc = svc.with_reactors(reactors);
    let max_conns = a.usize("max-conns").unwrap_or_else(|e| fail(&e));
    if max_conns > 0 {
        svc = svc.with_max_conns(max_conns);
    }
    let idle_timeout = a.f64("idle-timeout").unwrap_or_else(|e| fail(&e));
    if idle_timeout > 0.0 {
        svc = svc.with_idle_timeout(std::time::Duration::from_secs_f64(idle_timeout));
    }
    let max_wbuf = a.usize("max-wbuf").unwrap_or_else(|e| fail(&e));
    if max_wbuf > 0 {
        svc = svc.with_max_wbuf(max_wbuf);
    }
    let max_pending = a.usize("max-pending").unwrap_or_else(|e| fail(&e));
    if max_pending > 0 {
        svc = svc.with_max_pending(max_pending);
    }
    let shutdown_drain = a.f64("shutdown-drain").unwrap_or_else(|e| fail(&e));
    if shutdown_drain >= 0.0 {
        svc = svc.with_shutdown_drain(std::time::Duration::from_secs_f64(shutdown_drain));
    }
    let default_deadline = a.usize("default-deadline").unwrap_or_else(|e| fail(&e));
    if default_deadline as u64 > multicloud::coordinator::spec::MAX_DEADLINE_MS {
        fail(&format!(
            "--default-deadline must be <= {} ms",
            multicloud::coordinator::spec::MAX_DEADLINE_MS
        ));
    }
    if default_deadline > 0 {
        svc = svc.with_default_deadline(std::time::Duration::from_millis(default_deadline as u64));
    }

    // --transport wins; the legacy --event-loop switch still works for
    // scripts that predate it.
    let choice = a
        .choice("transport", &["epoll", "poll", "threaded", "auto"])
        .unwrap_or_else(|e| fail(&e));
    match choice.as_str() {
        "epoll" => {
            if !multicloud::util::net::epoll_supported() {
                fail("--transport epoll: not supported on this platform (use poll or auto)");
            }
            svc = svc.with_transport(Transport::Epoll);
        }
        "poll" => {
            if !multicloud::util::net::supported() {
                fail("--transport poll: not supported on this platform (use threaded or auto)");
            }
            svc = svc.with_transport(Transport::Poll);
        }
        "threaded" => svc = svc.with_transport(Transport::Threaded),
        _ => {
            // auto: defer to --event-loop, then to the platform default.
            let mode = a.choice("event-loop", &["on", "off", "auto"]).unwrap_or_else(|e| fail(&e));
            match mode.as_str() {
                "on" => {
                    if !multicloud::util::net::supported() {
                        fail("--event-loop on: not supported on this platform (use off or auto)");
                    }
                    svc = svc.with_event_loop(true);
                }
                "off" => svc = svc.with_event_loop(false),
                _ => {} // best transport where supported
            }
        }
    }
    let svc = Arc::new(svc);
    let stop = Arc::new(AtomicBool::new(false));
    let transport = svc.transport().name();
    let max_conns = svc.effective_max_conns();
    let reactors = if svc.event_loop_enabled() { svc.reactor_count() } else { 0 };
    let (port, handle) = svc.serve(a.get("addr"), stop).unwrap_or_else(|e| fail(&e.to_string()));
    println!(
        "listening on port {port} (transport {transport}, {reactors} reactors, \
         max {max_conns} connections; \
         codecs: json lines [default] | length-prefixed binary, negotiated per connection via \
         {{\"op\":\"hello\",\"codec\":...}} or a 0xB1 first byte; \
         op: optimize | batch | list_workloads | list_methods | stats | clear_cache | ping)"
    );
    handle.join().ok();
    0
}

fn cmd_inspect(args: &[String]) -> i32 {
    let c = Command::new("inspect", "ground-truth response surface of one workload")
        .opt("workload", "kmeans:santander", "workload id")
        .opt("target", "cost", "cost | time")
        .opt("top", "10", "show the best N configurations")
        .opt("dataset", "", "offline dataset CSV (empty = regenerate)");
    let a = parse_or_exit(c, args);
    let ds = load_dataset(a.get("dataset"));
    let w = ds
        .workload_index(a.get("workload"))
        .unwrap_or_else(|| fail(&format!("unknown workload '{}'", a.get("workload"))));
    let target = Target::parse(a.get("target")).unwrap_or_else(|| fail("bad target"));
    let grid = ds.domain.full_grid();
    let mut vals: Vec<(usize, f64)> =
        (0..grid.len()).map(|c| (c, ds.mean_value(w, c, target))).collect();
    vals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let top = a.usize("top").unwrap().min(vals.len());
    println!(
        "{} / {} — best {top} of {} configurations (mean of {} reps):",
        a.get("workload"),
        target.name(),
        grid.len(),
        ds.reps
    );
    let header = vec!["rank".to_string(), "configuration".to_string(), target.name().to_string()];
    let rows: Vec<Vec<String>> = vals[..top]
        .iter()
        .enumerate()
        .map(|(i, (c, v))| vec![format!("{}", i + 1), grid[*c].label(&ds.domain), format!("{v:.4}")])
        .collect();
    println!("{}", multicloud::report::ascii_table(&header, &rows));
    println!("random-strategy expectation: {:.4}", ds.random_strategy_value(w, target));
    0
}
