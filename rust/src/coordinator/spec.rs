//! Declarative experiment specifications (the config system behind the
//! `experiment` CLI subcommand).
//!
//! A spec is a JSON file describing a regret grid:
//!
//! ```json
//! {
//!   "name": "smac-vs-cb",
//!   "methods": ["smac", "cb-rbfopt", "rs"],
//!   "budgets": [11, 33, 88],
//!   "seeds": 25,
//!   "targets": ["cost", "time"],
//!   "workloads": ["xgboost:santander", "kmeans:buzz"]
//! }
//! ```
//!
//! `workloads` is optional (default: all 30). Optional knobs:
//! `"measure_mode": "single_draw" | "mean" | "p90"` (deterministic modes
//! run memoized ledgers), `"trial_workers": N` (parallel arm execution
//! inside each bandit trial; results are identical at any setting —
//! `0` sizes it adaptively as `max(1, cores / grid workers)`), and
//! `"online": true | {"ticks": T, "reoptimize_every": R}` (dynamic-market
//! re-optimization mode: trials report final-tick regret).
//! Methods are validated against the optimizer registry + predictive
//! baselines at parse time so a bad spec fails before any compute is
//! spent.

use crate::coordinator::experiment::PREDICTORS;
use crate::dataset::objective::MeasureMode;
use crate::dataset::Target;
use crate::optimizers::ALL_OPTIMIZERS;
use crate::util::json::{parse, Value};

/// Upper bound on per-trial arm workers: total parallelism is grid
/// workers × trial workers, so this stays small and explicit.
pub const MAX_TRIAL_WORKERS: usize = 64;

/// Upper bound on a request's `deadline_ms` (one hour). Deadlines above
/// this are almost certainly unit confusion (seconds vs milliseconds),
/// and rejecting them keeps the reactor's deadline math safely away from
/// `Instant` overflow.
pub const MAX_DEADLINE_MS: u64 = 3_600_000;

/// Upper bound on online-mode market ticks: every re-optimization epoch
/// re-runs a full budgeted search, so this caps worst-case work per
/// request the same way `MAX_BATCH` caps batch fan-out.
pub const MAX_TICKS: u64 = 256;

/// Knobs of the `online` re-optimization mode: a recurring workload's
/// incumbent configuration re-scored each market tick and re-searched on
/// a schedule (and immediately when its provider's capacity is revoked).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineParams {
    /// Logical market ticks to simulate (1..=[`MAX_TICKS`]).
    pub ticks: u64,
    /// Re-optimize every N ticks; 0 = only when the incumbent's
    /// provider is revoked.
    pub reoptimize_every: u64,
}

impl Default for OnlineParams {
    fn default() -> Self {
        OnlineParams { ticks: 12, reoptimize_every: 4 }
    }
}

impl OnlineParams {
    /// Parse an optional `"online"` field: absent or `false` → `None`,
    /// `true` → defaults, an object → explicit knobs. Every numeric is
    /// validated (floats, negatives, and oversize values are structured
    /// errors, never truncation).
    pub fn parse_field(v: Option<&Value>) -> Result<Option<OnlineParams>, String> {
        let obj = match v {
            None | Some(Value::Bool(false)) => return Ok(None),
            Some(Value::Bool(true)) => return Ok(Some(OnlineParams::default())),
            Some(o @ Value::Obj(_)) => o,
            Some(_) => return Err("online must be a boolean or an object".into()),
        };
        let defaults = OnlineParams::default();
        let ticks = match obj.get("ticks") {
            None => defaults.ticks,
            Some(t) => t.as_usize().ok_or("online.ticks must be a positive integer")? as u64,
        };
        if ticks == 0 || ticks > MAX_TICKS {
            return Err(format!("online.ticks must be in 1..={MAX_TICKS}"));
        }
        let reoptimize_every = match obj.get("reoptimize_every") {
            None => defaults.reoptimize_every,
            Some(t) => t
                .as_usize()
                .ok_or("online.reoptimize_every must be a non-negative integer")?
                as u64,
        };
        if reoptimize_every > MAX_TICKS {
            return Err(format!("online.reoptimize_every must be in 0..={MAX_TICKS}"));
        }
        Ok(Some(OnlineParams { ticks, reoptimize_every }))
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    pub name: String,
    pub methods: Vec<String>,
    pub budgets: Vec<usize>,
    pub seeds: usize,
    pub targets: Vec<Target>,
    /// Workload ids; empty = all.
    pub workloads: Vec<String>,
    /// Measurement aggregation per evaluation (default `single_draw`).
    pub measure_mode: MeasureMode,
    /// Arm workers per trial (default 1 = sequential arms; 0 = adaptive:
    /// the grid sizes it as `max(1, cores / grid workers)`). Results are
    /// bit-identical at any setting.
    pub trial_workers: usize,
    /// Dynamic-market online mode: when set, every trial runs the
    /// re-optimization loop over market ticks and its regret is the
    /// final-tick regret (predictive baselines, which have no
    /// re-optimization budget, are rejected at parse time).
    pub online: Option<OnlineParams>,
}

impl ExperimentSpec {
    pub fn parse(text: &str) -> Result<ExperimentSpec, String> {
        let v = parse(text)?;
        let name = v
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("experiment")
            .to_string();

        let str_list = |key: &str| -> Result<Vec<String>, String> {
            match v.get(key) {
                None => Ok(Vec::new()),
                Some(Value::Arr(a)) => a
                    .iter()
                    .map(|e| {
                        e.as_str().map(str::to_string).ok_or_else(|| format!("{key}: non-string"))
                    })
                    .collect(),
                Some(_) => Err(format!("{key} must be an array of strings")),
            }
        };

        let methods = str_list("methods")?;
        if methods.is_empty() {
            return Err("spec needs a non-empty 'methods' array".into());
        }
        for m in &methods {
            if !ALL_OPTIMIZERS.contains(&m.as_str()) && !PREDICTORS.contains(&m.as_str()) {
                return Err(format!("unknown method '{m}'"));
            }
        }

        let budgets: Vec<usize> = match v.get("budgets") {
            None => vec![11, 22, 33, 44, 55, 66, 77, 88],
            Some(Value::Arr(a)) => {
                let mut out = Vec::new();
                for e in a {
                    out.push(e.as_usize().ok_or("budgets: non-integer")?);
                }
                if out.is_empty() || out.iter().any(|&b| b == 0) {
                    return Err("budgets must be positive".into());
                }
                out
            }
            Some(_) => return Err("budgets must be an array".into()),
        };

        let seeds = match v.get("seeds") {
            None => 10,
            Some(s) => s.as_usize().ok_or("seeds must be a non-negative integer")?,
        };
        if seeds == 0 {
            return Err("seeds must be >= 1".into());
        }

        let targets = match v.get("targets") {
            None => vec![Target::Time, Target::Cost],
            Some(Value::Arr(a)) => {
                let mut out = Vec::new();
                for e in a {
                    let s = e.as_str().ok_or("targets: non-string")?;
                    out.push(Target::parse(s).ok_or_else(|| format!("bad target '{s}'"))?);
                }
                out
            }
            Some(_) => return Err("targets must be an array".into()),
        };

        let measure_mode = match v.get("measure_mode") {
            None => MeasureMode::SingleDraw,
            Some(m) => {
                let s = m.as_str().ok_or("measure_mode must be a string")?;
                MeasureMode::parse(s)
                    .ok_or_else(|| format!("bad measure_mode '{s}' (single_draw | mean | p90)"))?
            }
        };

        let trial_workers = match v.get("trial_workers") {
            None => 1,
            Some(w) => w.as_usize().ok_or("trial_workers must be a non-negative integer")?,
        };
        if trial_workers > MAX_TRIAL_WORKERS {
            return Err(format!(
                "trial_workers must be in 0..={MAX_TRIAL_WORKERS} (0 = adaptive)"
            ));
        }

        let online = OnlineParams::parse_field(v.get("online"))?;
        if online.is_some() {
            if let Some(m) = methods.iter().find(|m| PREDICTORS.contains(&m.as_str())) {
                return Err(format!(
                    "online mode requires search methods; '{m}' is a predictive baseline"
                ));
            }
        }

        Ok(ExperimentSpec {
            name,
            methods,
            budgets,
            seeds,
            targets,
            workloads: str_list("workloads")?,
            measure_mode,
            trial_workers,
            online,
        })
    }

    pub fn load(path: &str) -> Result<ExperimentSpec, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_spec_roundtrip() {
        let s = ExperimentSpec::parse(
            r#"{"name":"x","methods":["rs","smac"],"budgets":[11,33],
                "seeds":5,"targets":["cost"],"workloads":["kmeans:buzz"]}"#,
        )
        .unwrap();
        assert_eq!(s.name, "x");
        assert_eq!(s.methods, vec!["rs", "smac"]);
        assert_eq!(s.budgets, vec![11, 33]);
        assert_eq!(s.seeds, 5);
        assert_eq!(s.targets, vec![Target::Cost]);
        assert_eq!(s.workloads, vec!["kmeans:buzz"]);
    }

    #[test]
    fn defaults_fill_in() {
        let s = ExperimentSpec::parse(r#"{"methods":["rs"]}"#).unwrap();
        assert_eq!(s.budgets.len(), 8);
        assert_eq!(s.seeds, 10);
        assert_eq!(s.targets.len(), 2);
        assert!(s.workloads.is_empty());
        assert_eq!(s.measure_mode, MeasureMode::SingleDraw);
        assert_eq!(s.trial_workers, 1);
        assert_eq!(s.online, None);
    }

    #[test]
    fn online_knobs_parse_and_validate() {
        let on = ExperimentSpec::parse(r#"{"methods":["rs"],"online":true}"#).unwrap();
        assert_eq!(on.online, Some(OnlineParams::default()));
        let explicit = ExperimentSpec::parse(
            r#"{"methods":["cb-rbfopt"],"online":{"ticks":20,"reoptimize_every":0}}"#,
        )
        .unwrap();
        assert_eq!(explicit.online, Some(OnlineParams { ticks: 20, reoptimize_every: 0 }));
        let off = ExperimentSpec::parse(r#"{"methods":["rs"],"online":false}"#).unwrap();
        assert_eq!(off.online, None);

        // Malformed numerics and shapes are structured errors.
        for bad in [
            r#"{"methods":["rs"],"online":{"ticks":0}}"#,
            r#"{"methods":["rs"],"online":{"ticks":-3}}"#,
            r#"{"methods":["rs"],"online":{"ticks":1.5}}"#,
            r#"{"methods":["rs"],"online":{"ticks":1e300}}"#,
            r#"{"methods":["rs"],"online":{"ticks":999}}"#,
            r#"{"methods":["rs"],"online":{"reoptimize_every":-1}}"#,
            r#"{"methods":["rs"],"online":"yes"}"#,
            r#"{"methods":["predict-rf"],"online":true}"#,
        ] {
            assert!(ExperimentSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn measure_mode_and_trial_workers_parse() {
        let s = ExperimentSpec::parse(
            r#"{"methods":["cb-rbfopt"],"measure_mode":"mean","trial_workers":4}"#,
        )
        .unwrap();
        assert_eq!(s.measure_mode, MeasureMode::Mean);
        assert_eq!(s.trial_workers, 4);
        // 0 = adaptive sizing, resolved by the grid at run time.
        let auto =
            ExperimentSpec::parse(r#"{"methods":["rs"],"trial_workers":0}"#).unwrap();
        assert_eq!(auto.trial_workers, 0);
    }

    #[test]
    fn validation_errors() {
        assert!(ExperimentSpec::parse("{}").is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["warp-drive"]}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"budgets":[0]}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"seeds":0}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"targets":["speed"]}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"measure_mode":"median"}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"trial_workers":1000}"#).is_err());
        assert!(ExperimentSpec::parse(r#"{"methods":["rs"],"trial_workers":-1}"#).is_err());
        assert!(ExperimentSpec::parse("not json").is_err());
    }

    #[test]
    fn predictors_are_valid_methods() {
        assert!(ExperimentSpec::parse(r#"{"methods":["predict-rf"]}"#).is_ok());
    }
}
