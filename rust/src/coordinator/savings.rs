//! Production savings analysis (paper §IV-E, Figure 4).
//!
//! For each workload: run the optimizer once (budget B), pay its search
//! expense C_opt, then run the workload N more times at the returned
//! configuration's expense R_opt; compare the total against N runs at the
//! random-strategy expectation R_rand. Per-seed quantities are averaged
//! before the savings formula is applied (matching "the savings were
//! computed using each algorithm's results averaged over all random
//! seeds, separately for each workload").

use super::experiment::{run_trial, TrialSpec};
use crate::dataset::{OfflineDataset, Target};
use crate::metrics;
use crate::surrogate::Backend;
use crate::util::stats::BoxStats;
use crate::util::threadpool::parallel_map_progress;

#[derive(Clone, Debug)]
pub struct SavingsConfig {
    pub budget: usize,
    pub production_runs: usize,
    pub seeds: usize,
    pub workers: usize,
}

impl Default for SavingsConfig {
    fn default() -> Self {
        // Paper: B = 33, N = 64.
        SavingsConfig { budget: 33, production_runs: 64, seeds: 50, workers: crate::util::threadpool::default_workers() }
    }
}

/// Per-method savings distribution across workloads.
#[derive(Clone, Debug)]
pub struct SavingsDistribution {
    pub method: String,
    pub target: Target,
    /// One savings value per workload (seed-averaged).
    pub per_workload: Vec<f64>,
}

impl SavingsDistribution {
    pub fn box_stats(&self) -> BoxStats {
        BoxStats::compute(&self.per_workload)
    }
}

/// Compute savings distributions for the given methods.
pub fn savings_analysis(
    ds: &OfflineDataset,
    backend: &dyn Backend,
    methods: &[String],
    target: Target,
    cfg: &SavingsConfig,
) -> Vec<SavingsDistribution> {
    let workloads = ds.workload_count();
    let mut specs = Vec::new();
    for method in methods {
        for workload in 0..workloads {
            for seed in 0..cfg.seeds {
                specs.push(TrialSpec {
                    method: method.clone(),
                    workload,
                    target,
                    budget: cfg.budget,
                    seed: seed as u64,
                    ..TrialSpec::default()
                });
            }
        }
    }
    let results = parallel_map_progress(
        specs,
        cfg.workers,
        |spec| run_trial(ds, backend, spec),
        |_, _| {},
    );

    methods
        .iter()
        .map(|method| {
            let per_workload: Vec<f64> = (0..workloads)
                .map(|w| {
                    let rs: Vec<&_> = results
                        .iter()
                        .filter(|r| r.spec.method == *method && r.spec.workload == w)
                        .collect();
                    assert!(!rs.is_empty());
                    let c_opt = crate::util::stats::mean(
                        &rs.iter().map(|r| r.search_expense).collect::<Vec<_>>(),
                    );
                    let r_opt = crate::util::stats::mean(
                        &rs.iter().map(|r| r.chosen_value).collect::<Vec<_>>(),
                    );
                    let r_rand = ds.random_strategy_value(w, target);
                    metrics::savings(c_opt, r_opt, r_rand, cfg.production_runs)
                })
                .collect();
            SavingsDistribution { method: method.clone(), target, per_workload }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    #[test]
    fn exhaustive_search_has_strictly_negative_savings() {
        // The paper's headline strawman result: testing all 88 configs can
        // never pay off within 64 production runs on this domain.
        let ds = OfflineDataset::generate(50, 3);
        let backend = NativeBackend;
        let cfg = SavingsConfig { seeds: 2, workers: 4, ..Default::default() };
        let out = savings_analysis(
            &ds,
            &backend,
            &["exhaustive".to_string()],
            Target::Cost,
            &cfg,
        );
        let dist = &out[0].per_workload;
        assert_eq!(dist.len(), 30);
        assert!(
            dist.iter().all(|&s| s < 0.0),
            "exhaustive produced positive savings: {:?}",
            dist.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        );
    }

    #[test]
    fn cheap_search_with_good_config_gives_positive_median_savings() {
        let ds = OfflineDataset::generate(51, 3);
        let backend = NativeBackend;
        let cfg = SavingsConfig { seeds: 3, workers: 4, ..Default::default() };
        let out = savings_analysis(
            &ds,
            &backend,
            &["cb-rbfopt".to_string()],
            Target::Cost,
            &cfg,
        );
        let b = out[0].box_stats();
        assert!(b.median > 0.0, "median savings {}", b.median);
    }
}
