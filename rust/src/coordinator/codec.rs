//! Wire framing and codecs: the one place the service's byte-level
//! protocol rules live.
//!
//! Until PR 7 the framing contract (1 MiB frame cap, newline split,
//! non-UTF-8 close, blank-line skip) was implemented twice — once in the
//! threaded transport's `read_frame`, once in the event loop's
//! `extract_frames` — with parity pinned only by tests. Both transports
//! now consume one incremental [`FrameScanner`], so divergence is
//! structurally impossible, and the scanner carries a codec seam:
//!
//! * [`JsonLinesCodec`] — the default wire format since PR 3:
//!   newline-delimited JSON objects. Frames are split by scanning for
//!   `\n`; blank (all-ASCII-whitespace) frames are skipped.
//! * [`BinaryCodec`] — a compact length-prefixed format for high-volume
//!   clients: each frame is a 4-byte little-endian payload length
//!   followed by that many bytes of JSON. Splitting is O(1) — no
//!   byte-by-byte newline scan — and the frame cap is enforced from the
//!   length prefix alone, *before* any payload is buffered. Zero-length
//!   frames are skipped (the blank-frame rule's binary analogue).
//!
//! A connection selects its codec per the `AnyCodec::from_name` shape
//! (see SNIPPETS.md: turbomcp-wire / remoc / malachite):
//!
//! * **Magic-byte sniff** — a first byte of [`BINARY_MAGIC`] (`0xB1`,
//!   an invalid UTF-8 lead byte, so it can never open a JSON-lines
//!   frame) switches the scanner to the binary codec and is consumed.
//! * **First-frame hello** — `{"op":"hello","codec":"json"|"binary"}`
//!   as the first frame answers `{"ok":true,"codec":<name>}` encoded in
//!   the *current* codec, then switches. An unknown name answers one
//!   JSON error and the connection closes ([`Greeting::Reject`]).
//!
//! Everything transports need is here: [`FrameScanner`] (framing),
//! [`from_name`] (negotiation), [`greet`] (first-frame hello
//! handling), and [`oversize_message`] (the single definition of the
//! frame-cap error, so the PR 5 class of per-transport divergence in
//! oversize handling cannot recur).

use crate::util::json::{parse_bytes, Value};

/// Largest accepted request frame in bytes. For JSON lines this is one
/// line, newline excluded; for the binary codec it caps the declared
/// payload length. A connection that exceeds it gets one error response
/// and a close — on every transport — so a garbage client cannot
/// balloon server memory through an endless unterminated frame.
pub const MAX_FRAME: usize = 1 << 20;

/// First byte of a binary-codec connection. An invalid UTF-8 lead byte,
/// so no JSON-lines client can ever send it first by accident.
pub const BINARY_MAGIC: u8 = 0xB1;

/// Codec wire names, in negotiation-advertisement order.
pub const CODEC_NAMES: [&str; 2] = ["json", "binary"];

/// The frame-cap violation, defined once for every transport and codec.
pub fn oversize_message() -> String {
    format!("frame larger than {MAX_FRAME} bytes")
}

/// Fatal framing failure: the frame (or its declared length) exceeds
/// [`MAX_FRAME`]. The connection owes one error response, then closes.
#[derive(Debug, PartialEq, Eq)]
pub struct Oversize;

/// Why a frame failed to decode into a request.
pub enum DecodeError {
    /// Valid UTF-8 but not valid JSON: answer an error response and
    /// keep the connection alive (a client bug, not a protocol break).
    Malformed(String),
    /// Not UTF-8 at all: close cleanly without a response (the peer is
    /// not speaking this protocol; bytes written back could confuse it
    /// further).
    Fatal,
}

/// One complete frame located inside a scan buffer: the payload lies at
/// `buf[start..end]`, and `consumed` bytes (payload plus framing) are
/// finished once the payload is taken.
pub struct RawFrame {
    pub start: usize,
    pub end: usize,
    pub consumed: usize,
}

/// One wire format: how frames are split off the byte stream, decoded
/// into requests, and how response payloads are framed back. Static
/// instances only ([`JSON_LINES`], [`BINARY`]) — a connection holds a
/// `&'static dyn Codec`, so switching codecs is a pointer swap.
pub trait Codec: Send + Sync {
    /// Wire name used by negotiation, `stats`, and the test matrix.
    fn name(&self) -> &'static str;

    /// Try to split one frame off the front of `buf`. `Ok(None)` means
    /// more bytes are needed; `Err(Oversize)` means the frame (complete
    /// or not) already violates [`MAX_FRAME`].
    fn split_frame(&self, buf: &[u8]) -> Result<Option<RawFrame>, Oversize>;

    /// Whether a split-off payload carries no request and is skipped
    /// (blank line / zero-length binary frame).
    fn is_blank(&self, payload: &[u8]) -> bool {
        payload.is_empty()
    }

    /// Decode one frame payload into a request value.
    fn decode_request(&self, payload: &[u8]) -> Result<Value, DecodeError>;

    /// Frame a JSON payload for the wire. Used for responses on the
    /// server and requests on clients — both directions frame alike.
    fn encode_frame(&self, payload: &str, out: &mut Vec<u8>);
}

/// Decode a JSON payload, classifying failures per the shared contract:
/// non-UTF-8 is fatal (close), anything else is a malformed request
/// (error response). Both codecs carry JSON payloads, so both use this.
fn decode_json(payload: &[u8]) -> Result<Value, DecodeError> {
    parse_bytes(payload).map_err(|e| {
        if std::str::from_utf8(payload).is_err() {
            DecodeError::Fatal
        } else {
            DecodeError::Malformed(e)
        }
    })
}

/// Newline-delimited JSON (the default codec).
pub struct JsonLinesCodec;

/// Length-prefixed JSON: 4-byte little-endian payload length, then the
/// payload bytes.
pub struct BinaryCodec;

/// The static JSON-lines codec instance.
pub static JSON_LINES: JsonLinesCodec = JsonLinesCodec;

/// The static binary codec instance.
pub static BINARY: BinaryCodec = BinaryCodec;

/// Resolve a negotiated codec name (`AnyCodec::from_name` shape).
pub fn from_name(name: &str) -> Option<&'static dyn Codec> {
    match name {
        "json" => Some(&JSON_LINES),
        "binary" => Some(&BINARY),
        _ => None,
    }
}

impl Codec for JsonLinesCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn split_frame(&self, buf: &[u8]) -> Result<Option<RawFrame>, Oversize> {
        match buf.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if pos > MAX_FRAME {
                    return Err(Oversize);
                }
                Ok(Some(RawFrame { start: 0, end: pos, consumed: pos + 1 }))
            }
            None => {
                // Unterminated data past the cap is oversize *now*, not
                // whenever the newline finally lands (or never does).
                if buf.len() > MAX_FRAME {
                    return Err(Oversize);
                }
                Ok(None)
            }
        }
    }

    fn is_blank(&self, payload: &[u8]) -> bool {
        payload.iter().all(u8::is_ascii_whitespace)
    }

    fn decode_request(&self, payload: &[u8]) -> Result<Value, DecodeError> {
        decode_json(payload)
    }

    fn encode_frame(&self, payload: &str, out: &mut Vec<u8>) {
        out.extend_from_slice(payload.as_bytes());
        out.push(b'\n');
    }
}

impl Codec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn split_frame(&self, buf: &[u8]) -> Result<Option<RawFrame>, Oversize> {
        if buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        // The cap is enforced from the prefix alone: an oversized frame
        // is rejected before a single payload byte is buffered.
        if len > MAX_FRAME {
            return Err(Oversize);
        }
        if buf.len() < 4 + len {
            return Ok(None);
        }
        Ok(Some(RawFrame { start: 4, end: 4 + len, consumed: 4 + len }))
    }

    fn decode_request(&self, payload: &[u8]) -> Result<Value, DecodeError> {
        decode_json(payload)
    }

    fn encode_frame(&self, payload: &str, out: &mut Vec<u8>) {
        // Responses are written straight from the payload string (a
        // cached hit's pre-serialized entry): one length prefix, one
        // copy, no newline scan and no UTF-8 validation pass.
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(payload.as_bytes());
    }
}

/// The shared incremental frame scanner: both transports feed raw
/// socket bytes in with [`push`](FrameScanner::push) and pull complete
/// frame payloads out with [`next_frame`](FrameScanner::next_frame).
/// Owns the connection's active codec — the magic-byte sniff happens
/// here, and a hello-negotiated switch ([`set_codec`]
/// (FrameScanner::set_codec)) re-scans any bytes already buffered under
/// the new codec, so a pipelined `hello` + binary burst in one TCP
/// segment parses correctly.
pub struct FrameScanner {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted on the next push).
    pos: usize,
    codec: &'static dyn Codec,
    /// First byte of the connection not yet seen: the magic sniff is
    /// still pending.
    sniff: bool,
}

impl Default for FrameScanner {
    fn default() -> FrameScanner {
        FrameScanner::new()
    }
}

impl FrameScanner {
    /// A scanner in the default state: JSON lines, magic sniff armed.
    pub fn new() -> FrameScanner {
        FrameScanner { buf: Vec::new(), pos: 0, codec: &JSON_LINES, sniff: true }
    }

    /// The connection's active codec.
    pub fn codec(&self) -> &'static dyn Codec {
        self.codec
    }

    /// Switch codecs (hello negotiation). Bytes already buffered are
    /// re-scanned under the new codec; the magic sniff is disarmed.
    pub fn set_codec(&mut self, codec: &'static dyn Codec) {
        self.codec = codec;
        self.sniff = false;
    }

    /// Bytes buffered but not yet consumed (unterminated partial frame
    /// plus anything not yet pulled).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Discard everything buffered (the oversize path: the connection
    /// is closing, leftover bytes must not balloon memory).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.pos = 0;
    }

    /// Feed raw bytes off the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Pull the next complete, non-blank frame payload, if one is
    /// buffered. `Err(Oversize)` is terminal for the connection: the
    /// caller answers [`oversize_message`] once and closes.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, Oversize> {
        loop {
            if self.sniff {
                match self.buf.get(self.pos) {
                    None => return Ok(None),
                    Some(&BINARY_MAGIC) => {
                        self.pos += 1;
                        self.codec = &BINARY;
                        self.sniff = false;
                    }
                    Some(_) => self.sniff = false,
                }
            }
            let rest = &self.buf[self.pos..];
            match self.codec.split_frame(rest)? {
                None => return Ok(None),
                Some(raw) => {
                    let payload = rest[raw.start..raw.end].to_vec();
                    self.pos += raw.consumed;
                    if self.codec.is_blank(&payload) {
                        continue;
                    }
                    return Ok(Some(payload));
                }
            }
        }
    }
}

/// How to treat the first frame of a connection (hello negotiation).
pub enum Greeting {
    /// A normal request: serve it under the scanner's current codec.
    Request,
    /// A valid hello: send `reply` (already framed in the pre-switch
    /// codec), then continue under `next`.
    Switch { reply: Vec<u8>, next: &'static dyn Codec },
    /// A hello naming an unknown codec: send `reply` (one JSON error),
    /// then close.
    Reject { reply: Vec<u8> },
}

/// Classify the first frame of a connection. Anything that is not a
/// well-formed `{"op":"hello",...}` — including unparseable garbage —
/// is a [`Greeting::Request`] and takes the normal request path (which
/// owns the error/close behavior for malformed frames). Only the first
/// frame is greeted; a later `hello` reaches the request handler and is
/// answered with an error there.
pub fn greet(payload: &[u8], current: &'static dyn Codec) -> Greeting {
    // Cheap pre-filter: a hello necessarily contains the word "hello",
    // so the overwhelmingly common non-hello first frame skips the
    // synchronous parse entirely.
    if !payload.windows(5).any(|w| w == b"hello") {
        return Greeting::Request;
    }
    let Ok(req) = current.decode_request(payload) else {
        return Greeting::Request;
    };
    if req.get("op").and_then(Value::as_str) != Some("hello") {
        return Greeting::Request;
    }
    let name = match req.get("codec") {
        // A bare hello acknowledges the codec already in effect.
        None => current.name(),
        Some(v) => v.as_str().unwrap_or(""),
    };
    match from_name(name) {
        Some(next) => {
            let ack = Value::obj(vec![
                ("ok", true.into()),
                ("codec", Value::str(next.name())),
            ])
            .to_string_compact();
            let mut reply = Vec::new();
            current.encode_frame(&ack, &mut reply);
            Greeting::Switch { reply, next }
        }
        None => {
            let err = Value::obj(vec![
                ("ok", false.into()),
                (
                    "error",
                    format!("unknown codec '{name}' (available: {})", CODEC_NAMES.join(", "))
                        .into(),
                ),
            ])
            .to_string_compact();
            let mut reply = Vec::new();
            current.encode_frame(&err, &mut reply);
            Greeting::Reject { reply }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(scanner: &mut FrameScanner) -> Vec<String> {
        let mut out = Vec::new();
        while let Ok(Some(f)) = scanner.next_frame() {
            out.push(String::from_utf8(f).unwrap());
        }
        out
    }

    #[test]
    fn json_lines_split_blank_skip_and_partial() {
        let mut s = FrameScanner::new();
        s.push(b"{\"op\":\"ping\"}\n\n  \t\r\n{\"a\":1}\n{\"tail");
        assert_eq!(frames(&mut s), vec!["{\"op\":\"ping\"}", "{\"a\":1}"]);
        assert_eq!(s.buffered(), 6, "partial frame stays buffered");
        s.push(b"\":2}\n");
        assert_eq!(frames(&mut s), vec!["{\"tail\":2}"]);
        assert_eq!(s.buffered(), 0);
    }

    #[test]
    fn byte_by_byte_trickle_assembles_one_frame() {
        let mut s = FrameScanner::new();
        for &b in b"{\"op\":\"ping\"}" {
            s.push(&[b]);
            assert!(matches!(s.next_frame(), Ok(None)));
        }
        s.push(b"\n");
        assert_eq!(frames(&mut s), vec!["{\"op\":\"ping\"}"]);
    }

    #[test]
    fn json_oversize_terminated_and_unterminated() {
        // Terminated frame one past the cap.
        let mut s = FrameScanner::new();
        let mut big = vec![b'x'; MAX_FRAME + 1];
        big.push(b'\n');
        s.push(&big);
        assert!(s.next_frame().is_err());
        // Unterminated data past the cap trips without any newline.
        let mut s = FrameScanner::new();
        s.push(&vec![b'x'; MAX_FRAME + 1]);
        assert!(s.next_frame().is_err());
        // Exactly at the cap is fine.
        let mut s = FrameScanner::new();
        let mut ok = vec![b'x'; MAX_FRAME];
        ok.push(b'\n');
        s.push(&ok);
        assert_eq!(s.next_frame().unwrap().unwrap().len(), MAX_FRAME);
    }

    #[test]
    fn magic_sniff_switches_to_binary() {
        let mut s = FrameScanner::new();
        assert_eq!(s.codec().name(), "json");
        let payload = br#"{"op":"ping"}"#;
        let mut wire = vec![BINARY_MAGIC];
        wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        wire.extend_from_slice(payload);
        s.push(&wire);
        assert_eq!(frames(&mut s), vec![r#"{"op":"ping"}"#]);
        assert_eq!(s.codec().name(), "binary");
    }

    #[test]
    fn binary_length_prefix_split_across_pushes() {
        let payload = br#"{"op":"stats"}"#;
        let mut wire = vec![BINARY_MAGIC];
        wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        wire.extend_from_slice(payload);
        // Trickle the whole thing one byte at a time: the frame must
        // appear exactly once, exactly when the last byte lands.
        let mut s = FrameScanner::new();
        for &b in &wire[..wire.len() - 1] {
            s.push(&[b]);
            assert!(s.next_frame().unwrap().is_none(), "no frame before the last byte");
        }
        s.push(&wire[wire.len() - 1..]);
        assert_eq!(frames(&mut s), vec![r#"{"op":"stats"}"#]);
    }

    #[test]
    fn binary_zero_length_frames_are_skipped() {
        let payload = br#"{"op":"ping"}"#;
        let mut s = FrameScanner::new();
        let mut wire = vec![BINARY_MAGIC];
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        wire.extend_from_slice(payload);
        s.push(&wire);
        assert_eq!(frames(&mut s), vec![r#"{"op":"ping"}"#]);
    }

    #[test]
    fn binary_oversize_rejected_from_the_prefix_alone() {
        let mut s = FrameScanner::new();
        let mut wire = vec![BINARY_MAGIC];
        wire.extend_from_slice(&((MAX_FRAME + 1) as u32).to_le_bytes());
        wire.extend_from_slice(b"only a few payload bytes");
        s.push(&wire);
        assert!(s.next_frame().is_err(), "cap must trip before the payload arrives");
        // A declared length of exactly MAX_FRAME is accepted.
        let mut s = FrameScanner::new();
        let mut wire = vec![BINARY_MAGIC];
        wire.extend_from_slice(&(MAX_FRAME as u32).to_le_bytes());
        wire.extend_from_slice(&vec![b'z'; MAX_FRAME]);
        s.push(&wire);
        assert_eq!(s.next_frame().unwrap().unwrap().len(), MAX_FRAME);
    }

    #[test]
    fn set_codec_rescans_buffered_bytes() {
        // A pipelined hello + binary frame in one push: after the
        // caller switches codecs, the already-buffered binary frame
        // parses under the new rules.
        let payload = br#"{"op":"ping"}"#;
        let mut s = FrameScanner::new();
        let mut wire = Vec::new();
        wire.extend_from_slice(b"{\"op\":\"hello\",\"codec\":\"binary\"}\n");
        wire.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        wire.extend_from_slice(payload);
        s.push(&wire);
        let hello = s.next_frame().unwrap().unwrap();
        match greet(&hello, s.codec()) {
            Greeting::Switch { next, .. } => s.set_codec(next),
            _ => panic!("hello must negotiate"),
        }
        assert_eq!(frames(&mut s), vec![r#"{"op":"ping"}"#]);
    }

    #[test]
    fn greet_outcomes() {
        let cur: &'static dyn Codec = &JSON_LINES;
        // Normal requests, garbage, and non-first-position shapes pass
        // through untouched.
        assert!(matches!(greet(br#"{"op":"ping"}"#, cur), Greeting::Request));
        assert!(matches!(greet(b"!! not json with hello in it !!", cur), Greeting::Request));
        assert!(matches!(greet(br#"{"note":"say hello"}"#, cur), Greeting::Request));
        // A valid switch acks in the current (json) framing.
        match greet(br#"{"op":"hello","codec":"binary"}"#, cur) {
            Greeting::Switch { reply, next } => {
                assert_eq!(next.name(), "binary");
                assert_eq!(reply, b"{\"ok\":true,\"codec\":\"binary\"}\n");
            }
            _ => panic!("expected a switch"),
        }
        // A bare hello acks the codec already in effect.
        match greet(br#"{"op":"hello"}"#, cur) {
            Greeting::Switch { next, .. } => assert_eq!(next.name(), "json"),
            _ => panic!("expected an ack"),
        }
        // Unknown names are rejected with one JSON error.
        match greet(br#"{"op":"hello","codec":"msgpack"}"#, cur) {
            Greeting::Reject { reply } => {
                let text = String::from_utf8(reply).unwrap();
                assert!(text.contains("unknown codec 'msgpack'"), "{text}");
                assert!(text.contains("json"), "{text}");
            }
            _ => panic!("expected a reject"),
        }
    }

    #[test]
    fn decode_classifies_failures() {
        assert!(matches!(decode_json(br#"{"op":"ping"}"#), Ok(_)));
        assert!(matches!(decode_json(b"not json"), Err(DecodeError::Malformed(_))));
        assert!(matches!(decode_json(&[0xff, 0xfe, 0x80]), Err(DecodeError::Fatal)));
    }

    #[test]
    fn from_name_resolves_exactly_the_advertised_set() {
        for name in CODEC_NAMES {
            assert_eq!(from_name(name).unwrap().name(), name);
        }
        assert!(from_name("msgpack").is_none());
        assert!(from_name("").is_none());
    }

    #[test]
    fn encode_frame_roundtrips_through_split() {
        for codec in [&JSON_LINES as &'static dyn Codec, &BINARY] {
            let mut wire = Vec::new();
            codec.encode_frame(r#"{"ok":true}"#, &mut wire);
            codec.encode_frame(r#"{"ok":false}"#, &mut wire);
            let mut s = FrameScanner::new();
            s.set_codec(codec);
            s.push(&wire);
            assert_eq!(frames(&mut s), vec![r#"{"ok":true}"#, r#"{"ok":false}"#], "{}", codec.name());
        }
    }
}
