//! Experiment grids: run many (method, workload, target, budget, seed)
//! trials in parallel and aggregate regrets (the engine behind Figures
//! 2-3 and the savings analysis).

use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use crate::dataset::{OfflineDataset, Target};
use crate::metrics;
use crate::optimizers::{by_name, SearchContext};
use crate::predictors::ernest::LinearPredictor;
use crate::predictors::paris::ParisPredictor;
use crate::surrogate::Backend;
use crate::util::cancel::CancelToken;
use crate::util::rng::Rng;
use crate::util::threadpool::{
    default_workers, parallel_map_progress, parallel_map_progress_spawn,
};

/// Names of the predictive baselines (no budget axis).
pub const PREDICTORS: [&str; 2] = ["predict-linear", "predict-rf"];

/// One trial to execute.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub method: String,
    pub workload: usize,
    pub target: Target,
    pub budget: usize,
    pub seed: u64,
    /// Worker threads for parallel arm execution inside this trial (the
    /// bandit optimizers). Results are bit-identical at any setting, so
    /// this is deliberately excluded from the seed-derivation hash; total
    /// parallelism is grid workers × trial workers.
    pub trial_workers: usize,
    /// How one evaluation aggregates the stored repetitions.
    /// Deterministic modes (Mean/P90) run the ledger memoized: repeat
    /// proposals replay recorded values and are charged as search
    /// expense only once (the "cache measurements" deployment).
    pub measure_mode: MeasureMode,
}

impl Default for TrialSpec {
    fn default() -> Self {
        TrialSpec {
            method: "rs".into(),
            workload: 0,
            target: Target::Cost,
            budget: 11,
            seed: 0,
            trial_workers: 1,
            measure_mode: MeasureMode::SingleDraw,
        }
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub spec: TrialSpec,
    /// Ground-truth (mean) value of the configuration the method returned.
    pub chosen_value: f64,
    /// Regret vs the workload's true optimum.
    pub regret: f64,
    /// Search expense: sum of the target metric over all evaluations.
    pub search_expense: f64,
    pub evals: usize,
    /// Best-so-far observed value after each evaluation (the ledger's
    /// convergence curve; the service returns it under `include_trace`).
    pub trace: Vec<f64>,
    /// Why the search stopped early (`"disconnect"` / `"deadline"` /
    /// `"shutdown"`), or `None` for a complete run. A cancelled result is
    /// the exact prefix of the uncancelled run: completed evaluations are
    /// never altered (the token is only checked between pulls).
    pub cancelled: Option<&'static str>,
    /// Budget pulls cancellation saved (0 for complete runs).
    pub pulls_saved: usize,
}

/// Size a trial ledger, memoized when the measure mode is deterministic.
fn new_ledger<'a>(
    source: &'a LookupObjective<'a>,
    budget: usize,
    memoize: bool,
) -> EvalLedger<'a> {
    let ledger = EvalLedger::new(source, budget);
    if memoize { ledger.with_memo() } else { ledger }
}

/// Run a single trial. Seeds are decorrelated per (method, workload,
/// target, budget, seed) so grid order cannot matter — `trial_workers`
/// and `measure_mode` are deliberately not mixed in (workers never
/// change results; the mode changes the measurement itself).
pub fn run_trial(ds: &OfflineDataset, backend: &dyn Backend, spec: &TrialSpec) -> TrialResult {
    run_trial_with(ds, backend, spec, None)
}

/// [`run_trial`] with an optional cooperative cancellation token.
///
/// The token is threaded into the trial's [`EvalLedger`] (and from there
/// into every shard the optimizer splits off), where it is checked
/// **between pulls**: completed evaluations are never altered, merge
/// order is untouched, and the first pull is always honored so the
/// result is non-empty. The token is deliberately excluded from seed
/// derivation — a cancelled trial's history is the bit-identical prefix
/// of the uncancelled trial's.
///
/// Predictive baselines (`predict-linear` / `predict-rf`) ignore the
/// token: their online cost is fixed and tiny, and they drive the ledger
/// through `must_eval`, which treats a refused pull as a bug.
pub fn run_trial_with(
    ds: &OfflineDataset,
    backend: &dyn Backend,
    spec: &TrialSpec,
    cancel: Option<&CancelToken>,
) -> TrialResult {
    let mut label = Rng::new(spec.seed);
    // Mix the spec into the stream label deterministically.
    let mut h: u64 = 0x9E3779B97F4A7C15;
    for b in spec.method.bytes() {
        h = h.wrapping_mul(0x100000001b3) ^ b as u64;
    }
    h ^= (spec.workload as u64) << 32 | spec.budget as u64;
    h ^= match spec.target {
        Target::Time => 0x1111_1111,
        Target::Cost => 0x2222_2222,
    };
    let mut rng = label.fork(h);
    let obj_seed = rng.next_u64();

    let source =
        LookupObjective::new(ds, spec.workload, spec.target, spec.measure_mode, obj_seed);
    // Deterministic measure modes exploit ledger memoization: repeat
    // proposals replay the recorded value and C_opt only charges distinct
    // configurations.
    let memoize = spec.measure_mode.deterministic();

    // Every trial runs against a ledger; expense/evals/trace are read back
    // from it uniformly instead of being re-derived from source internals.
    // Predictive baselines have no budget axis: their ledger is sized to
    // their fixed, known online cost (still landing in the accounting).
    let (chosen, search_expense, evals, trace, cancelled, pulls_saved) =
        match spec.method.as_str() {
            "predict-linear" => {
                let mut ledger = new_ledger(&source, ds.domain.size(), memoize);
                let chosen = LinearPredictor.run(&ds.domain, &mut ledger).chosen;
                (chosen, ledger.total_expense(), ledger.evals(), ledger.trace().to_vec(), None, 0)
            }
            "predict-rf" => {
                let mut ledger = new_ledger(&source, 2 * ds.domain.provider_count(), memoize);
                let chosen = ParisPredictor::default()
                    .run(ds, spec.workload, spec.target, &mut ledger)
                    .chosen;
                (chosen, ledger.total_expense(), ledger.evals(), ledger.trace().to_vec(), None, 0)
            }
            name => {
                let opt = by_name(name).unwrap_or_else(|| panic!("unknown method {name}"));
                let ctx = SearchContext::new(&ds.domain, spec.target, backend)
                    .with_arm_workers(spec.trial_workers);
                let mut ledger =
                    new_ledger(&source, opt.provisioned_budget(&ctx, spec.budget), memoize);
                if let Some(token) = cancel {
                    ledger = ledger.with_cancel(token.clone());
                }
                let chosen = opt.run(&ctx, &mut ledger, &mut rng).best_config;
                (
                    chosen,
                    ledger.total_expense(),
                    ledger.evals(),
                    ledger.trace().to_vec(),
                    ledger.cancelled(),
                    ledger.pulls_saved(),
                )
            }
        };

    let chosen_value = source.ground_truth(&chosen);
    let (_, true_min) = ds.true_min(spec.workload, spec.target);
    TrialResult {
        spec: spec.clone(),
        chosen_value,
        regret: metrics::regret(chosen_value, true_min),
        search_expense,
        evals,
        trace,
        cancelled,
        pulls_saved,
    }
}

/// Regret curve of one method: mean regret per budget, aggregated over all
/// workloads (seed-mean first, workload-mean second).
#[derive(Clone, Debug)]
pub struct RegretCurve {
    pub method: String,
    pub target: Target,
    pub budgets: Vec<usize>,
    pub mean_regret: Vec<f64>,
}

/// Grid description for a regret experiment (Figures 2-3).
pub struct RegretGrid<'a> {
    pub ds: &'a OfflineDataset,
    pub backend: &'a dyn Backend,
    pub methods: Vec<String>,
    pub budgets: Vec<usize>,
    pub seeds: usize,
    pub targets: Vec<Target>,
    pub workers: usize,
    /// Worker threads per trial for parallel arm execution (bandit
    /// methods). Total parallelism is `workers × trial_workers`; the grid
    /// defaults to 1 so saturating the cores with trials stays the
    /// default and nested parallelism is an explicit opt-in (useful for
    /// small grids of expensive bandit trials). `0` sizes it adaptively
    /// as `max(1, cores / grid workers)` — results are bit-identical at
    /// any setting either way.
    pub trial_workers: usize,
    /// Measure mode for every trial; deterministic modes run memoized
    /// ledgers (the "cache measurements" deployment preset).
    pub measure_mode: MeasureMode,
    pub verbose: bool,
    /// Workload indices to include (empty = all).
    pub workload_filter: Vec<usize>,
}

impl<'a> RegretGrid<'a> {
    pub fn new(ds: &'a OfflineDataset, backend: &'a dyn Backend) -> Self {
        RegretGrid {
            ds,
            backend,
            methods: Vec::new(),
            budgets: vec![11, 22, 33, 44, 55, 66, 77, 88],
            seeds: 50,
            targets: vec![Target::Time, Target::Cost],
            workers: default_workers(),
            trial_workers: 1,
            measure_mode: MeasureMode::SingleDraw,
            verbose: false,
            workload_filter: Vec::new(),
        }
    }

    /// Execute the full grid; returns one curve per (method, target).
    /// Predictive methods get a single "budget" (their fixed online cost)
    /// replicated across the budget axis, as in Figure 2's flat lines.
    pub fn run(&self) -> Vec<RegretCurve> {
        let workloads = self.ds.workload_count();
        let included: Vec<usize> = if self.workload_filter.is_empty() {
            (0..workloads).collect()
        } else {
            self.workload_filter.clone()
        };
        // Adaptive arm workers (trial_workers = 0): split the machine
        // across the grid workers — the ROADMAP "adaptive trial_workers"
        // sizing. Purely a latency knob; trial results are identical.
        let trial_workers = if self.trial_workers == 0 {
            (default_workers() / self.workers.max(1)).max(1)
        } else {
            self.trial_workers
        };
        let mut specs: Vec<TrialSpec> = Vec::new();
        for target in &self.targets {
            for method in &self.methods {
                let is_pred = PREDICTORS.contains(&method.as_str());
                let budgets: Vec<usize> =
                    if is_pred { vec![0] } else { self.budgets.clone() };
                for &budget in &budgets {
                    for &workload in &included {
                        // Predictors are deterministic given the dataset —
                        // a single seed suffices (their "seed" axis only
                        // shuffles SingleDraw measurement draws).
                        let seeds = if is_pred { self.seeds.min(5) } else { self.seeds };
                        for seed in 0..seeds {
                            specs.push(TrialSpec {
                                method: method.clone(),
                                workload,
                                target: *target,
                                budget,
                                seed: seed as u64,
                                trial_workers,
                                measure_mode: self.measure_mode,
                            });
                        }
                    }
                }
            }
        }

        let total = specs.len();
        let verbose = self.verbose;
        let run_one = |spec: &TrialSpec| run_trial(self.ds, self.backend, spec);
        let report = move |done: usize, _: usize| {
            if verbose && (done % 500 == 0 || done == total) {
                eprintln!("  [experiment] {done}/{total} trials");
            }
        };
        // Trials normally run on the process worker team. With nested
        // arm workers the trial level gets dedicated threads instead: a
        // team-executed trial would run its own arm fan-out inline (see
        // util::threadpool), so the nested level would never engage.
        let results: Vec<TrialResult> = if trial_workers > 1 {
            parallel_map_progress_spawn(specs, self.workers, run_one, report)
        } else {
            parallel_map_progress(specs, self.workers, run_one, report)
        };

        // Aggregate.
        let mut curves = Vec::new();
        for target in &self.targets {
            for method in &self.methods {
                let is_pred = PREDICTORS.contains(&method.as_str());
                let budgets: Vec<usize> = if is_pred { vec![0] } else { self.budgets.clone() };
                let mut mean_regret = Vec::with_capacity(budgets.len());
                for &budget in &budgets {
                    let mut per_workload: Vec<Vec<f64>> = vec![Vec::new(); workloads];
                    for r in &results {
                        if r.spec.method == *method
                            && r.spec.target == *target
                            && r.spec.budget == budget
                        {
                            per_workload[r.spec.workload].push(r.regret);
                        }
                    }
                    mean_regret.push(metrics::mean_regret_over_workloads(&per_workload));
                }
                // Replicate predictor point across the budget axis.
                let (budgets, mean_regret) = if is_pred {
                    (self.budgets.clone(), vec![mean_regret[0]; self.budgets.len()])
                } else {
                    (budgets, mean_regret)
                };
                curves.push(RegretCurve {
                    method: method.clone(),
                    target: *target,
                    budgets,
                    mean_regret,
                });
            }
        }
        curves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    #[test]
    fn run_trial_is_deterministic_per_spec() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "rs".into(),
            workload: 2,
            target: Target::Cost,
            budget: 11,
            seed: 4,
            ..TrialSpec::default()
        };
        let a = run_trial(&ds, &backend, &spec);
        let b = run_trial(&ds, &backend, &spec);
        assert_eq!(a.regret, b.regret);
        assert_eq!(a.search_expense, b.search_expense);
        assert!(a.regret >= 0.0);
        // The convergence trace is part of the deterministic result:
        // one best-so-far point per evaluation, non-increasing.
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.trace.len(), a.evals);
        assert!(a.trace.windows(2).all(|w| w[1] <= w[0]));
    }

    /// `trial_workers` is a pure wall-clock knob: bandit trials produce
    /// bit-identical results at any worker count.
    #[test]
    fn trial_workers_do_not_change_results() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        for method in ["cb-cherrypick", "cb-rbfopt", "rb", "cherrypick-x3", "bilal-x3"] {
            let base = TrialSpec {
                method: method.into(),
                workload: 5,
                target: Target::Time,
                budget: 22,
                seed: 3,
                ..TrialSpec::default()
            };
            let seq = run_trial(&ds, &backend, &base);
            for workers in [2usize, 4] {
                let par = run_trial(
                    &ds,
                    &backend,
                    &TrialSpec { trial_workers: workers, ..base.clone() },
                );
                assert_eq!(seq.chosen_value.to_bits(), par.chosen_value.to_bits(), "{method}");
                assert_eq!(seq.regret.to_bits(), par.regret.to_bits(), "{method}");
                assert_eq!(
                    seq.search_expense.to_bits(),
                    par.search_expense.to_bits(),
                    "{method}"
                );
                assert_eq!(seq.evals, par.evals, "{method}");
            }
        }
    }

    /// The memoized Mean-mode preset: a repeat-heavy method (CherryPick
    /// allows repeat proposals; a budget above the grid size forces them)
    /// reports lower C_opt under Mean than under SingleDraw, because memo
    /// hits replay recorded measurements instead of paying again.
    #[test]
    fn mean_mode_memoization_cuts_search_expense_for_repeat_heavy_methods() {
        let ds = OfflineDataset::generate(44, 3);
        let backend = NativeBackend;
        // Budget above the grid size forces repeat evaluations for any
        // method; with-replacement RS repeats statistically and
        // CherryPick repeats by acquisition design.
        for method in ["rs", "cherrypick-x1"] {
            let base = TrialSpec {
                method: method.into(),
                workload: 3,
                target: Target::Cost,
                budget: ds.domain.size() + 12,
                seed: 1,
                ..TrialSpec::default()
            };
            let single = run_trial(&ds, &backend, &base);
            let mean = run_trial(
                &ds,
                &backend,
                &TrialSpec { measure_mode: MeasureMode::Mean, ..base.clone() },
            );
            assert_eq!(single.evals, mean.evals, "{method}: both modes use the full budget");
            assert!(
                mean.search_expense < single.search_expense,
                "{method}: memoized Mean C_opt {} should undercut SingleDraw C_opt {}",
                mean.search_expense,
                single.search_expense
            );
        }
    }

    /// A pre-fired token stops the trial after its guaranteed first
    /// pull; the truncated trace is the bit-identical prefix of the
    /// uncancelled trial's, and the result is flagged with the reason.
    #[test]
    fn cancelled_trial_is_a_bit_identical_prefix() {
        use crate::util::cancel::{CancelReason, CancelToken};
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "rs".into(),
            workload: 1,
            target: Target::Cost,
            budget: 22,
            seed: 7,
            ..TrialSpec::default()
        };
        let full = run_trial(&ds, &backend, &spec);
        assert_eq!(full.cancelled, None);
        assert_eq!(full.pulls_saved, 0);
        assert_eq!(full.evals, 22);

        let token = CancelToken::new();
        token.cancel(CancelReason::Disconnect);
        let cut = run_trial_with(&ds, &backend, &spec, Some(&token));
        assert_eq!(cut.cancelled, Some("disconnect"));
        assert_eq!(cut.evals, 1, "exactly the guaranteed first pull");
        assert_eq!(cut.pulls_saved, 21);
        let full_prefix: Vec<u64> = full.trace[..1].iter().map(|v| v.to_bits()).collect();
        let cut_trace: Vec<u64> = cut.trace.iter().map(|v| v.to_bits()).collect();
        assert_eq!(cut_trace, full_prefix, "completed prefix diverged");
    }

    #[test]
    fn small_grid_produces_curves_for_every_method() {
        let ds = OfflineDataset::generate(41, 3);
        let backend = NativeBackend;
        let mut grid = RegretGrid::new(&ds, &backend);
        grid.methods = vec!["rs".into(), "predict-linear".into()];
        grid.budgets = vec![11, 22];
        grid.seeds = 2;
        grid.targets = vec![Target::Cost];
        grid.workers = 2;
        let curves = grid.run();
        assert_eq!(curves.len(), 2);
        for c in &curves {
            assert_eq!(c.budgets.len(), 2);
            assert_eq!(c.mean_regret.len(), 2);
            assert!(c.mean_regret.iter().all(|r| r.is_finite() && *r >= 0.0));
        }
        // The predictor's line is flat.
        let pred = curves.iter().find(|c| c.method == "predict-linear").unwrap();
        assert_eq!(pred.mean_regret[0], pred.mean_regret[1]);
    }

    #[test]
    fn rs_regret_decreases_with_budget_on_average() {
        let ds = OfflineDataset::generate(42, 3);
        let backend = NativeBackend;
        let mut grid = RegretGrid::new(&ds, &backend);
        grid.methods = vec!["rs".into()];
        grid.budgets = vec![11, 88];
        grid.seeds = 10;
        grid.targets = vec![Target::Time];
        grid.workers = 4;
        let curves = grid.run();
        assert!(
            curves[0].mean_regret[1] < curves[0].mean_regret[0],
            "RS regret should fall with budget: {:?}",
            curves[0].mean_regret
        );
    }
}
