//! Experiment grids: run many (method, workload, target, budget, seed)
//! trials in parallel and aggregate regrets (the engine behind Figures
//! 2-3 and the savings analysis).

use crate::coordinator::spec::OnlineParams;
use crate::dataset::objective::{EvalLedger, LookupObjective, MeasureMode};
use crate::dataset::{OfflineDataset, Target};
use crate::domain::Config;
use crate::metrics;
use crate::optimizers::{by_name, SearchContext};
use crate::predictors::ernest::LinearPredictor;
use crate::predictors::paris::ParisPredictor;
use crate::simulator::market;
use crate::surrogate::Backend;
use crate::util::cancel::CancelToken;
use crate::util::rng::{splitmix64, Rng};
use crate::util::threadpool::{
    default_workers, parallel_map_progress, parallel_map_progress_spawn,
};

/// Names of the predictive baselines (no budget axis).
pub const PREDICTORS: [&str; 2] = ["predict-linear", "predict-rf"];

/// One trial to execute.
#[derive(Clone, Debug)]
pub struct TrialSpec {
    pub method: String,
    pub workload: usize,
    pub target: Target,
    pub budget: usize,
    pub seed: u64,
    /// Worker threads for parallel arm execution inside this trial (the
    /// bandit optimizers). Results are bit-identical at any setting, so
    /// this is deliberately excluded from the seed-derivation hash; total
    /// parallelism is grid workers × trial workers.
    pub trial_workers: usize,
    /// How one evaluation aggregates the stored repetitions.
    /// Deterministic modes (Mean/P90) run the ledger memoized: repeat
    /// proposals replay recorded values and are charged as search
    /// expense only once (the "cache measurements" deployment).
    pub measure_mode: MeasureMode,
}

impl Default for TrialSpec {
    fn default() -> Self {
        TrialSpec {
            method: "rs".into(),
            workload: 0,
            target: Target::Cost,
            budget: 11,
            seed: 0,
            trial_workers: 1,
            measure_mode: MeasureMode::SingleDraw,
        }
    }
}

/// Outcome of one trial.
#[derive(Clone, Debug)]
pub struct TrialResult {
    pub spec: TrialSpec,
    /// Ground-truth (mean) value of the configuration the method returned.
    pub chosen_value: f64,
    /// Regret vs the workload's true optimum.
    pub regret: f64,
    /// Search expense: sum of the target metric over all evaluations.
    pub search_expense: f64,
    pub evals: usize,
    /// Best-so-far observed value after each evaluation (the ledger's
    /// convergence curve; the service returns it under `include_trace`).
    pub trace: Vec<f64>,
    /// Why the search stopped early (`"disconnect"` / `"deadline"` /
    /// `"shutdown"`), or `None` for a complete run. A cancelled result is
    /// the exact prefix of the uncancelled run: completed evaluations are
    /// never altered (the token is only checked between pulls).
    pub cancelled: Option<&'static str>,
    /// Budget pulls cancellation saved (0 for complete runs).
    pub pulls_saved: usize,
}

/// Size a trial ledger, memoized when the measure mode is deterministic.
fn new_ledger<'a>(
    source: &'a LookupObjective<'a>,
    budget: usize,
    memoize: bool,
) -> EvalLedger<'a> {
    let ledger = EvalLedger::new(source, budget);
    if memoize { ledger.with_memo() } else { ledger }
}

/// Run a single trial. Seeds are decorrelated per (method, workload,
/// target, budget, seed) so grid order cannot matter — `trial_workers`
/// and `measure_mode` are deliberately not mixed in (workers never
/// change results; the mode changes the measurement itself).
pub fn run_trial(ds: &OfflineDataset, backend: &dyn Backend, spec: &TrialSpec) -> TrialResult {
    run_trial_with(ds, backend, spec, None)
}

/// [`run_trial`] with an optional cooperative cancellation token.
///
/// The token is threaded into the trial's [`EvalLedger`] (and from there
/// into every shard the optimizer splits off), where it is checked
/// **between pulls**: completed evaluations are never altered, merge
/// order is untouched, and the first pull is always honored so the
/// result is non-empty. The token is deliberately excluded from seed
/// derivation — a cancelled trial's history is the bit-identical prefix
/// of the uncancelled trial's.
///
/// Predictive baselines (`predict-linear` / `predict-rf`) ignore the
/// token: their online cost is fixed and tiny, and they drive the ledger
/// through `must_eval`, which treats a refused pull as a bug.
pub fn run_trial_with(
    ds: &OfflineDataset,
    backend: &dyn Backend,
    spec: &TrialSpec,
    cancel: Option<&CancelToken>,
) -> TrialResult {
    let mut label = Rng::new(spec.seed);
    // Mix the spec into the stream label deterministically.
    let mut h: u64 = 0x9E3779B97F4A7C15;
    for b in spec.method.bytes() {
        h = h.wrapping_mul(0x100000001b3) ^ b as u64;
    }
    h ^= (spec.workload as u64) << 32 | spec.budget as u64;
    h ^= match spec.target {
        Target::Time => 0x1111_1111,
        Target::Cost => 0x2222_2222,
    };
    let mut rng = label.fork(h);
    let obj_seed = rng.next_u64();

    let source =
        LookupObjective::new(ds, spec.workload, spec.target, spec.measure_mode, obj_seed);
    // Deterministic measure modes exploit ledger memoization: repeat
    // proposals replay the recorded value and C_opt only charges distinct
    // configurations.
    let memoize = spec.measure_mode.deterministic();

    // Every trial runs against a ledger; expense/evals/trace are read back
    // from it uniformly instead of being re-derived from source internals.
    // Predictive baselines have no budget axis: their ledger is sized to
    // their fixed, known online cost (still landing in the accounting).
    let (chosen, search_expense, evals, trace, cancelled, pulls_saved) =
        match spec.method.as_str() {
            "predict-linear" => {
                let mut ledger = new_ledger(&source, ds.domain.size(), memoize);
                let chosen = LinearPredictor.run(&ds.domain, &mut ledger).chosen;
                (chosen, ledger.total_expense(), ledger.evals(), ledger.trace().to_vec(), None, 0)
            }
            "predict-rf" => {
                let mut ledger = new_ledger(&source, 2 * ds.domain.provider_count(), memoize);
                let chosen = ParisPredictor::default()
                    .run(ds, spec.workload, spec.target, &mut ledger)
                    .chosen;
                (chosen, ledger.total_expense(), ledger.evals(), ledger.trace().to_vec(), None, 0)
            }
            name => {
                let opt = by_name(name).unwrap_or_else(|| panic!("unknown method {name}"));
                let ctx = SearchContext::new(&ds.domain, spec.target, backend)
                    .with_arm_workers(spec.trial_workers);
                let mut ledger =
                    new_ledger(&source, opt.provisioned_budget(&ctx, spec.budget), memoize);
                if let Some(token) = cancel {
                    ledger = ledger.with_cancel(token.clone());
                }
                let chosen = opt.run(&ctx, &mut ledger, &mut rng).best_config;
                (
                    chosen,
                    ledger.total_expense(),
                    ledger.evals(),
                    ledger.trace().to_vec(),
                    ledger.cancelled(),
                    ledger.pulls_saved(),
                )
            }
        };

    let chosen_value = source.ground_truth(&chosen);
    let (_, true_min) = ds.true_min(spec.workload, spec.target);
    TrialResult {
        spec: spec.clone(),
        chosen_value,
        regret: metrics::regret(chosen_value, true_min),
        search_expense,
        evals,
        trace,
        cancelled,
        pulls_saved,
    }
}

/// Outcome of one online-mode trial (dynamic market). `result` is the
/// final-tick summary shaped like a static [`TrialResult`] — except that
/// its `trace` is the **regret-over-time** series (one point per scored
/// tick, not a best-so-far curve) and `evals` / `search_expense` /
/// `pulls_saved` accumulate over every re-optimization epoch.
#[derive(Clone, Debug)]
pub struct OnlineOutcome {
    pub result: TrialResult,
    /// Regret of the incumbent at each tick vs the best configuration
    /// then *available* (revoked providers excluded) at that tick's
    /// prices. A pure function of the spec: two runs replay the
    /// byte-identical series.
    pub regret_over_time: Vec<f64>,
    /// Ticks at which the incumbent's provider was revoked; each forces
    /// an immediate re-optimization over the surviving providers.
    pub revocations: Vec<u64>,
    /// Re-optimization epochs after the initial tick-0 search
    /// (scheduled + revocation-forced).
    pub reoptimizations: usize,
    /// Cost/runtime Pareto front over available configs at the last
    /// scored tick: `(config label, mean runtime s, mean cost USD at
    /// that tick's prices)`, sorted by runtime ascending.
    pub pareto: Vec<(String, f64, f64)>,
}

/// Market-priced ground truth of `cfg` at `tick` (mean value, cost
/// scaled by the provider's effective price — same scaling as the
/// measurement path).
fn market_truth(
    ds: &OfflineDataset,
    workload: usize,
    target: Target,
    market_seed: u64,
    tick: u64,
    cfg: &Config,
) -> f64 {
    LookupObjective::new(ds, workload, target, MeasureMode::Mean, 0)
        .with_market(market_seed, tick)
        .ground_truth(cfg)
}

/// True minimum over the configurations *available* at `tick` (revoked
/// providers excluded) under that tick's prices. The market layer
/// guarantees at least one provider survives, so this is always finite.
fn best_available(
    ds: &OfflineDataset,
    workload: usize,
    target: Target,
    market_seed: u64,
    tick: u64,
    revoked: &[usize],
) -> f64 {
    ds.domain
        .full_grid()
        .iter()
        .filter(|c| !revoked.contains(&c.provider))
        .map(|c| market_truth(ds, workload, target, market_seed, tick, c))
        .fold(f64::INFINITY, f64::min)
}

/// Nondominated (runtime, cost) configurations among those available at
/// `tick`, minimizing both. Sorted by runtime ascending — cost then
/// descends along the front.
fn pareto_front(
    ds: &OfflineDataset,
    workload: usize,
    market_seed: u64,
    tick: u64,
    revoked: &[usize],
) -> Vec<(String, f64, f64)> {
    let mut pts: Vec<(String, f64, f64)> = ds
        .domain
        .full_grid()
        .iter()
        .filter(|c| !revoked.contains(&c.provider))
        .map(|c| {
            let cid = ds.domain.config_id(c);
            let t = ds.mean_value(workload, cid, Target::Time);
            let cost = ds.mean_value(workload, cid, Target::Cost)
                * market::effective_price(market_seed, c.provider, tick);
            (c.label(&ds.domain), t, cost)
        })
        .collect();
    pts.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.2.partial_cmp(&b.2).unwrap()));
    let mut front = Vec::new();
    let mut best_cost = f64::INFINITY;
    for p in pts {
        if p.2 < best_cost {
            best_cost = p.2;
            front.push(p);
        }
    }
    front
}

/// [`run_online_trial_with`] without a cancellation token.
pub fn run_online_trial(
    ds: &OfflineDataset,
    backend: &dyn Backend,
    spec: &TrialSpec,
    online: &OnlineParams,
) -> OnlineOutcome {
    run_online_trial_with(ds, backend, spec, online, None)
}

/// The dynamic-market online mode: hold an incumbent configuration for a
/// recurring workload while per-provider prices drift, spot discounts
/// come and go, and capacity gets revoked — re-scoring the incumbent
/// every logical tick and re-searching on a schedule (and immediately
/// when the incumbent's provider is revoked).
///
/// Per tick `t` in `0..online.ticks`:
/// 1. compute the tick's revoked-provider set from the market stream;
///    if the incumbent sits on revoked capacity, record the revocation
///    and force a re-optimization (CloudBandit re-pulls across the
///    surviving arms via [`SearchContext::with_revoked`]; methods
///    without provider awareness are post-placed on the best available
///    configuration their search observed);
/// 2. otherwise re-optimize when the schedule says so
///    (`t % reoptimize_every == 0`, `t > 0`); each epoch runs the
///    spec'd method with a *fresh* budget against a tick-decorrelated
///    measurement stream priced at that tick;
/// 3. score the incumbent's market-priced ground truth against the best
///    available configuration and append the regret to the trace.
///
/// Everything — prices, revocations, epoch seeds — derives from the
/// spec's label stream plus the logical tick, so the whole trajectory is
/// clock-free and bit-reproducible. Cancellation (deadline/disconnect)
/// is honored between pulls exactly as in [`run_trial_with`]: the tick
/// being searched is scored with whatever partial search it got, the
/// loop stops, and the outcome carries the reason plus accumulated
/// `pulls_saved`.
///
/// Panics if `spec.method` is a predictive baseline (the spec and
/// service layers reject that combination at parse time).
pub fn run_online_trial_with(
    ds: &OfflineDataset,
    backend: &dyn Backend,
    spec: &TrialSpec,
    online: &OnlineParams,
    cancel: Option<&CancelToken>,
) -> OnlineOutcome {
    // Same label derivation as `run_trial_with`, so an online trial's
    // streams are decorrelated from the static trial of the same spec.
    let mut label = Rng::new(spec.seed);
    let mut h: u64 = 0x9E3779B97F4A7C15;
    for b in spec.method.bytes() {
        h = h.wrapping_mul(0x100000001b3) ^ b as u64;
    }
    h ^= (spec.workload as u64) << 32 | spec.budget as u64;
    h ^= match spec.target {
        Target::Time => 0x1111_1111,
        Target::Cost => 0x2222_2222,
    };
    let mut rng = label.fork(h);
    let obj_seed = rng.next_u64();
    // The market stream is a SplitMix64 fork of the objective seed: one
    // more pure function of the spec, shared by every epoch.
    let mut ms = obj_seed ^ 0x6F6E_6C69_6E65_5F31;
    let market_seed = splitmix64(&mut ms);
    let providers = ds.domain.provider_count();

    let opt = by_name(&spec.method).unwrap_or_else(|| {
        panic!("online mode requires a search method, got '{}'", spec.method)
    });
    let memoize = spec.measure_mode.deterministic();
    let mut epoch_rng_base = rng.fork(0x0E50C);

    let mut regret_over_time = Vec::with_capacity(online.ticks as usize);
    let mut revocations = Vec::new();
    let mut reoptimizations = 0usize;
    let mut search_expense = 0.0;
    let mut evals = 0usize;
    let mut pulls_saved = 0usize;
    let mut cancelled: Option<&'static str> = None;
    let mut incumbent: Option<Config> = None;
    let mut last_tick = 0u64;

    for tick in 0..online.ticks {
        let revoked = market::revoked_providers(market_seed, providers, tick);
        let incumbent_revoked = incumbent.as_ref().is_some_and(|c| revoked.contains(&c.provider));
        if incumbent_revoked {
            revocations.push(tick);
        }
        let scheduled =
            online.reoptimize_every > 0 && tick > 0 && tick % online.reoptimize_every == 0;
        if cancelled.is_none() && (incumbent.is_none() || incumbent_revoked || scheduled) {
            // Each epoch draws from a tick-decorrelated measurement
            // stream priced at this tick's market.
            let mut es = obj_seed ^ tick.wrapping_mul(0xD1B5_4A32_D192_ED03);
            let epoch_seed = splitmix64(&mut es);
            let source = LookupObjective::new(
                ds,
                spec.workload,
                spec.target,
                spec.measure_mode,
                epoch_seed,
            )
            .with_market(market_seed, tick);
            let ctx = SearchContext::new(&ds.domain, spec.target, backend)
                .with_arm_workers(spec.trial_workers)
                .with_revoked(revoked.clone());
            let mut ledger =
                new_ledger(&source, opt.provisioned_budget(&ctx, spec.budget), memoize);
            if let Some(token) = cancel {
                ledger = ledger.with_cancel(token.clone());
            }
            let mut epoch_rng = epoch_rng_base.fork(tick);
            let mut chosen = opt.run(&ctx, &mut ledger, &mut epoch_rng).best_config;
            // Provider-oblivious methods may return a config on revoked
            // capacity: place the workload on the best available config
            // the search observed instead (last resort: the first
            // available grid config — arbitrary but deterministic).
            if revoked.contains(&chosen.provider) {
                chosen = ledger
                    .history()
                    .iter()
                    .filter(|(c, _)| !revoked.contains(&c.provider))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .map(|(c, _)| c.clone())
                    .or_else(|| {
                        ds.domain
                            .full_grid()
                            .into_iter()
                            .find(|c| !revoked.contains(&c.provider))
                    })
                    .expect("market layer always leaves a provider available");
            }
            search_expense += ledger.total_expense();
            evals += ledger.evals();
            pulls_saved += ledger.pulls_saved();
            if let Some(reason) = ledger.cancelled() {
                cancelled = Some(reason);
            }
            if tick > 0 {
                reoptimizations += 1;
            }
            incumbent = Some(chosen);
        }

        let inc = incumbent.as_ref().expect("tick 0 always searches");
        let value = market_truth(ds, spec.workload, spec.target, market_seed, tick, inc);
        let best = best_available(ds, spec.workload, spec.target, market_seed, tick, &revoked);
        regret_over_time.push(metrics::regret(value, best));
        last_tick = tick;
        if cancelled.is_some() {
            break;
        }
    }

    let final_revoked = market::revoked_providers(market_seed, providers, last_tick);
    let pareto = pareto_front(ds, spec.workload, market_seed, last_tick, &final_revoked);
    let incumbent = incumbent.expect("online loop always runs tick 0");
    let chosen_value =
        market_truth(ds, spec.workload, spec.target, market_seed, last_tick, &incumbent);
    let result = TrialResult {
        spec: spec.clone(),
        chosen_value,
        regret: *regret_over_time.last().expect("at least one tick is scored"),
        search_expense,
        evals,
        trace: regret_over_time.clone(),
        cancelled,
        pulls_saved,
    };
    OnlineOutcome { result, regret_over_time, revocations, reoptimizations, pareto }
}

/// Regret curve of one method: mean regret per budget, aggregated over all
/// workloads (seed-mean first, workload-mean second).
#[derive(Clone, Debug)]
pub struct RegretCurve {
    pub method: String,
    pub target: Target,
    pub budgets: Vec<usize>,
    pub mean_regret: Vec<f64>,
}

/// Grid description for a regret experiment (Figures 2-3).
pub struct RegretGrid<'a> {
    pub ds: &'a OfflineDataset,
    pub backend: &'a dyn Backend,
    pub methods: Vec<String>,
    pub budgets: Vec<usize>,
    pub seeds: usize,
    pub targets: Vec<Target>,
    pub workers: usize,
    /// Worker threads per trial for parallel arm execution (bandit
    /// methods). Total parallelism is `workers × trial_workers`; the grid
    /// defaults to 1 so saturating the cores with trials stays the
    /// default and nested parallelism is an explicit opt-in (useful for
    /// small grids of expensive bandit trials). `0` sizes it adaptively
    /// as `max(1, cores / grid workers)` — results are bit-identical at
    /// any setting either way.
    pub trial_workers: usize,
    /// Measure mode for every trial; deterministic modes run memoized
    /// ledgers (the "cache measurements" deployment preset).
    pub measure_mode: MeasureMode,
    pub verbose: bool,
    /// Workload indices to include (empty = all).
    pub workload_filter: Vec<usize>,
    /// Dynamic-market online mode: when set, every search-method trial
    /// runs [`run_online_trial`] (its summary regret is the *final-tick*
    /// regret vs the best then-available configuration). Predictive
    /// baselines stay static — they are market-oblivious flat lines by
    /// construction, and the spec layer rejects the combination anyway.
    pub online: Option<OnlineParams>,
}

impl<'a> RegretGrid<'a> {
    pub fn new(ds: &'a OfflineDataset, backend: &'a dyn Backend) -> Self {
        RegretGrid {
            ds,
            backend,
            methods: Vec::new(),
            budgets: vec![11, 22, 33, 44, 55, 66, 77, 88],
            seeds: 50,
            targets: vec![Target::Time, Target::Cost],
            workers: default_workers(),
            trial_workers: 1,
            measure_mode: MeasureMode::SingleDraw,
            verbose: false,
            workload_filter: Vec::new(),
            online: None,
        }
    }

    /// Execute the full grid; returns one curve per (method, target).
    /// Predictive methods get a single "budget" (their fixed online cost)
    /// replicated across the budget axis, as in Figure 2's flat lines.
    pub fn run(&self) -> Vec<RegretCurve> {
        let workloads = self.ds.workload_count();
        let included: Vec<usize> = if self.workload_filter.is_empty() {
            (0..workloads).collect()
        } else {
            self.workload_filter.clone()
        };
        // Adaptive arm workers (trial_workers = 0): split the machine
        // across the grid workers — the ROADMAP "adaptive trial_workers"
        // sizing. Purely a latency knob; trial results are identical.
        let trial_workers = if self.trial_workers == 0 {
            (default_workers() / self.workers.max(1)).max(1)
        } else {
            self.trial_workers
        };
        let mut specs: Vec<TrialSpec> = Vec::new();
        for target in &self.targets {
            for method in &self.methods {
                let is_pred = PREDICTORS.contains(&method.as_str());
                let budgets: Vec<usize> =
                    if is_pred { vec![0] } else { self.budgets.clone() };
                for &budget in &budgets {
                    for &workload in &included {
                        // Predictors are deterministic given the dataset —
                        // a single seed suffices (their "seed" axis only
                        // shuffles SingleDraw measurement draws).
                        let seeds = if is_pred { self.seeds.min(5) } else { self.seeds };
                        for seed in 0..seeds {
                            specs.push(TrialSpec {
                                method: method.clone(),
                                workload,
                                target: *target,
                                budget,
                                seed: seed as u64,
                                trial_workers,
                                measure_mode: self.measure_mode,
                            });
                        }
                    }
                }
            }
        }

        let total = specs.len();
        let verbose = self.verbose;
        let online = self.online;
        let run_one = |spec: &TrialSpec| match online {
            Some(params) if !PREDICTORS.contains(&spec.method.as_str()) => {
                run_online_trial(self.ds, self.backend, spec, &params).result
            }
            _ => run_trial(self.ds, self.backend, spec),
        };
        let report = move |done: usize, _: usize| {
            if verbose && (done % 500 == 0 || done == total) {
                eprintln!("  [experiment] {done}/{total} trials");
            }
        };
        // Trials normally run on the process worker team. With nested
        // arm workers the trial level gets dedicated threads instead: a
        // team-executed trial would run its own arm fan-out inline (see
        // util::threadpool), so the nested level would never engage.
        let results: Vec<TrialResult> = if trial_workers > 1 {
            parallel_map_progress_spawn(specs, self.workers, run_one, report)
        } else {
            parallel_map_progress(specs, self.workers, run_one, report)
        };

        // Aggregate.
        let mut curves = Vec::new();
        for target in &self.targets {
            for method in &self.methods {
                let is_pred = PREDICTORS.contains(&method.as_str());
                let budgets: Vec<usize> = if is_pred { vec![0] } else { self.budgets.clone() };
                let mut mean_regret = Vec::with_capacity(budgets.len());
                for &budget in &budgets {
                    let mut per_workload: Vec<Vec<f64>> = vec![Vec::new(); workloads];
                    for r in &results {
                        if r.spec.method == *method
                            && r.spec.target == *target
                            && r.spec.budget == budget
                        {
                            per_workload[r.spec.workload].push(r.regret);
                        }
                    }
                    mean_regret.push(metrics::mean_regret_over_workloads(&per_workload));
                }
                // Replicate predictor point across the budget axis.
                let (budgets, mean_regret) = if is_pred {
                    (self.budgets.clone(), vec![mean_regret[0]; self.budgets.len()])
                } else {
                    (budgets, mean_regret)
                };
                curves.push(RegretCurve {
                    method: method.clone(),
                    target: *target,
                    budgets,
                    mean_regret,
                });
            }
        }
        curves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    #[test]
    fn run_trial_is_deterministic_per_spec() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "rs".into(),
            workload: 2,
            target: Target::Cost,
            budget: 11,
            seed: 4,
            ..TrialSpec::default()
        };
        let a = run_trial(&ds, &backend, &spec);
        let b = run_trial(&ds, &backend, &spec);
        assert_eq!(a.regret, b.regret);
        assert_eq!(a.search_expense, b.search_expense);
        assert!(a.regret >= 0.0);
        // The convergence trace is part of the deterministic result:
        // one best-so-far point per evaluation, non-increasing.
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.trace.len(), a.evals);
        assert!(a.trace.windows(2).all(|w| w[1] <= w[0]));
    }

    /// `trial_workers` is a pure wall-clock knob: bandit trials produce
    /// bit-identical results at any worker count.
    #[test]
    fn trial_workers_do_not_change_results() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        for method in ["cb-cherrypick", "cb-rbfopt", "rb", "cherrypick-x3", "bilal-x3"] {
            let base = TrialSpec {
                method: method.into(),
                workload: 5,
                target: Target::Time,
                budget: 22,
                seed: 3,
                ..TrialSpec::default()
            };
            let seq = run_trial(&ds, &backend, &base);
            for workers in [2usize, 4] {
                let par = run_trial(
                    &ds,
                    &backend,
                    &TrialSpec { trial_workers: workers, ..base.clone() },
                );
                assert_eq!(seq.chosen_value.to_bits(), par.chosen_value.to_bits(), "{method}");
                assert_eq!(seq.regret.to_bits(), par.regret.to_bits(), "{method}");
                assert_eq!(
                    seq.search_expense.to_bits(),
                    par.search_expense.to_bits(),
                    "{method}"
                );
                assert_eq!(seq.evals, par.evals, "{method}");
            }
        }
    }

    /// The memoized Mean-mode preset: a repeat-heavy method (CherryPick
    /// allows repeat proposals; a budget above the grid size forces them)
    /// reports lower C_opt under Mean than under SingleDraw, because memo
    /// hits replay recorded measurements instead of paying again.
    #[test]
    fn mean_mode_memoization_cuts_search_expense_for_repeat_heavy_methods() {
        let ds = OfflineDataset::generate(44, 3);
        let backend = NativeBackend;
        // Budget above the grid size forces repeat evaluations for any
        // method; with-replacement RS repeats statistically and
        // CherryPick repeats by acquisition design.
        for method in ["rs", "cherrypick-x1"] {
            let base = TrialSpec {
                method: method.into(),
                workload: 3,
                target: Target::Cost,
                budget: ds.domain.size() + 12,
                seed: 1,
                ..TrialSpec::default()
            };
            let single = run_trial(&ds, &backend, &base);
            let mean = run_trial(
                &ds,
                &backend,
                &TrialSpec { measure_mode: MeasureMode::Mean, ..base.clone() },
            );
            assert_eq!(single.evals, mean.evals, "{method}: both modes use the full budget");
            assert!(
                mean.search_expense < single.search_expense,
                "{method}: memoized Mean C_opt {} should undercut SingleDraw C_opt {}",
                mean.search_expense,
                single.search_expense
            );
        }
    }

    /// A pre-fired token stops the trial after its guaranteed first
    /// pull; the truncated trace is the bit-identical prefix of the
    /// uncancelled trial's, and the result is flagged with the reason.
    #[test]
    fn cancelled_trial_is_a_bit_identical_prefix() {
        use crate::util::cancel::{CancelReason, CancelToken};
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "rs".into(),
            workload: 1,
            target: Target::Cost,
            budget: 22,
            seed: 7,
            ..TrialSpec::default()
        };
        let full = run_trial(&ds, &backend, &spec);
        assert_eq!(full.cancelled, None);
        assert_eq!(full.pulls_saved, 0);
        assert_eq!(full.evals, 22);

        let token = CancelToken::new();
        token.cancel(CancelReason::Disconnect);
        let cut = run_trial_with(&ds, &backend, &spec, Some(&token));
        assert_eq!(cut.cancelled, Some("disconnect"));
        assert_eq!(cut.evals, 1, "exactly the guaranteed first pull");
        assert_eq!(cut.pulls_saved, 21);
        let full_prefix: Vec<u64> = full.trace[..1].iter().map(|v| v.to_bits()).collect();
        let cut_trace: Vec<u64> = cut.trace.iter().map(|v| v.to_bits()).collect();
        assert_eq!(cut_trace, full_prefix, "completed prefix diverged");
    }

    #[test]
    fn small_grid_produces_curves_for_every_method() {
        let ds = OfflineDataset::generate(41, 3);
        let backend = NativeBackend;
        let mut grid = RegretGrid::new(&ds, &backend);
        grid.methods = vec!["rs".into(), "predict-linear".into()];
        grid.budgets = vec![11, 22];
        grid.seeds = 2;
        grid.targets = vec![Target::Cost];
        grid.workers = 2;
        let curves = grid.run();
        assert_eq!(curves.len(), 2);
        for c in &curves {
            assert_eq!(c.budgets.len(), 2);
            assert_eq!(c.mean_regret.len(), 2);
            assert!(c.mean_regret.iter().all(|r| r.is_finite() && *r >= 0.0));
        }
        // The predictor's line is flat.
        let pred = curves.iter().find(|c| c.method == "predict-linear").unwrap();
        assert_eq!(pred.mean_regret[0], pred.mean_regret[1]);
    }

    /// Acceptance criterion for the dynamic market: the same online spec
    /// run twice replays a byte-identical regret-over-time trace,
    /// revocation schedule, and Pareto front.
    #[test]
    fn online_trial_is_byte_identical_across_runs() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "cb-rbfopt".into(),
            workload: 2,
            target: Target::Cost,
            budget: 22,
            seed: 9,
            ..TrialSpec::default()
        };
        let online = OnlineParams { ticks: 12, reoptimize_every: 4 };
        let a = run_online_trial(&ds, &backend, &spec, &online);
        let b = run_online_trial(&ds, &backend, &spec, &online);
        let bits = |t: &[f64]| t.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&a.regret_over_time), bits(&b.regret_over_time));
        assert_eq!(a.revocations, b.revocations);
        assert_eq!(a.reoptimizations, b.reoptimizations);
        assert_eq!(a.pareto, b.pareto);
        assert_eq!(a.result.evals, b.result.evals);
        assert_eq!(a.result.search_expense.to_bits(), b.result.search_expense.to_bits());

        assert_eq!(a.regret_over_time.len(), online.ticks as usize);
        assert_eq!(a.result.trace, a.regret_over_time, "summary trace IS the regret series");
        assert!(a.regret_over_time.iter().all(|r| r.is_finite() && *r >= 0.0));
        // 12 ticks at reoptimize_every=4 schedules epochs at 4 and 8;
        // revocations can only add more.
        assert!(a.reoptimizations >= 2);
        assert!(!a.pareto.is_empty());
        assert!(a.pareto.windows(2).all(|w| w[0].1 <= w[1].1 && w[0].2 > w[1].2));
    }

    /// A revoked incumbent forces an immediate re-optimization onto
    /// surviving capacity: whenever the market revokes the incumbent's
    /// provider, the next incumbent never sits on a revoked provider.
    #[test]
    fn online_revocation_moves_the_incumbent_off_revoked_capacity() {
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        // Long horizon with no schedule: every re-optimization after tick
        // 0 is revocation-forced.
        let online = OnlineParams { ticks: 48, reoptimize_every: 0 };
        let mut saw_revocation = false;
        for seed in 0..6 {
            let spec = TrialSpec {
                method: "rs".into(),
                workload: 1,
                target: Target::Cost,
                budget: 11,
                seed,
                ..TrialSpec::default()
            };
            let out = run_online_trial(&ds, &backend, &spec, &online);
            saw_revocation |= !out.revocations.is_empty();
            assert_eq!(out.reoptimizations, out.revocations.len());
        }
        assert!(saw_revocation, "48 ticks x 6 seeds at 8% revocation rate must revoke");
    }

    /// Cancellation in online mode mirrors the static contract: the
    /// completed tick prefix is bit-identical to the uncancelled run and
    /// the saved pulls are accounted.
    #[test]
    fn cancelled_online_trial_keeps_a_bit_identical_tick_prefix() {
        use crate::util::cancel::{CancelReason, CancelToken};
        let ds = OfflineDataset::generate(40, 3);
        let backend = NativeBackend;
        let spec = TrialSpec {
            method: "rs".into(),
            workload: 3,
            target: Target::Cost,
            budget: 11,
            seed: 2,
            ..TrialSpec::default()
        };
        let online = OnlineParams { ticks: 8, reoptimize_every: 2 };
        let full = run_online_trial(&ds, &backend, &spec, &online);
        assert_eq!(full.result.cancelled, None);
        assert_eq!(full.result.pulls_saved, 0);

        let token = CancelToken::new();
        token.cancel(CancelReason::Disconnect);
        let cut = run_online_trial_with(&ds, &backend, &spec, &online, Some(&token));
        assert_eq!(cut.result.cancelled, Some("disconnect"));
        // Tick 0's search gets its guaranteed first pull, is scored, and
        // the loop stops.
        assert_eq!(cut.regret_over_time.len(), 1);
        assert_eq!(cut.result.evals, 1);
        assert_eq!(cut.result.pulls_saved, spec.budget - 1);
        assert_eq!(
            cut.regret_over_time[0].to_bits(),
            full.regret_over_time[0].to_bits(),
            "completed tick prefix diverged"
        );
    }

    /// The grid's online switch routes search methods through the online
    /// loop (deterministically) while predictors stay static.
    #[test]
    fn grid_online_mode_produces_finite_curves() {
        let ds = OfflineDataset::generate(43, 3);
        let backend = NativeBackend;
        let mut grid = RegretGrid::new(&ds, &backend);
        grid.methods = vec!["rs".into()];
        grid.budgets = vec![11];
        grid.seeds = 2;
        grid.targets = vec![Target::Cost];
        grid.workers = 2;
        grid.online = Some(OnlineParams { ticks: 4, reoptimize_every: 2 });
        let a = grid.run();
        let b = grid.run();
        assert_eq!(a.len(), 1);
        assert!(a[0].mean_regret.iter().all(|r| r.is_finite() && *r >= 0.0));
        assert_eq!(
            a[0].mean_regret.iter().map(|r| r.to_bits()).collect::<Vec<u64>>(),
            b[0].mean_regret.iter().map(|r| r.to_bits()).collect::<Vec<u64>>()
        );
    }

    #[test]
    fn rs_regret_decreases_with_budget_on_average() {
        let ds = OfflineDataset::generate(42, 3);
        let backend = NativeBackend;
        let mut grid = RegretGrid::new(&ds, &backend);
        grid.methods = vec!["rs".into()];
        grid.budgets = vec![11, 88];
        grid.seeds = 10;
        grid.targets = vec![Target::Time];
        grid.workers = 4;
        let curves = grid.run();
        assert!(
            curves[0].mean_regret[1] < curves[0].mean_regret[0],
            "RS regret should fall with budget: {:?}",
            curves[0].mean_regret
        );
    }
}
