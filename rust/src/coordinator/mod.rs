//! L3 coordinator: the experiment launcher and runtime.
//!
//! * [`codec`]      — the service's wire layer: one shared frame
//!   scanner plus pluggable per-connection codecs (JSON lines, binary).
//! * [`experiment`] — declarative experiment grids (method x workload x
//!   budget x seed x target) executed on the work-queue thread pool; the
//!   engine behind every figure and the CLI.
//! * [`savings`]    — the §IV-E production-savings analysis.
//! * [`service`]    — a line-delimited-JSON TCP service exposing the
//!   optimizer suite (the "request path": rust only, artifacts loaded
//!   once, python never involved).

pub mod codec;
pub mod experiment;
pub mod savings;
pub mod service;
pub mod spec;
