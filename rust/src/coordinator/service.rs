//! TCP optimization service: the long-running "request path" deployment.
//!
//! Line-delimited JSON over TCP. The server loads the offline dataset and
//! the PJRT artifacts once at startup; each request runs one optimization
//! and returns the recommended deployment. Python is never involved.
//!
//! Request:
//!   {"op": "optimize", "workload": "kmeans:santander", "target": "cost",
//!    "method": "cb-rbfopt", "budget": 33, "seed": 1,
//!    "trial_workers": 3, "measure_mode": "single_draw"}
//!   {"op": "list_workloads"}
//!   {"op": "list_methods"}
//!   {"op": "ping"}
//!
//! `trial_workers` (optional, default 1) runs the bandit optimizers'
//! arms in parallel inside the request — results are bit-identical at
//! any setting, only latency changes. `measure_mode` (optional, default
//! "single_draw") selects the evaluation aggregation; deterministic
//! modes run memoized.
//!
//! Response (optimize):
//!   {"ok": true, "config": "gcp/family=e2/...", "value": 0.123,
//!    "evals": 33, "search_expense": 4.56, "regret": 0.01}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::experiment::{run_trial, TrialSpec};
use crate::coordinator::spec::MAX_TRIAL_WORKERS;
use crate::dataset::objective::MeasureMode;
use crate::dataset::{OfflineDataset, Target};
use crate::optimizers::ALL_OPTIMIZERS;
use crate::surrogate::Backend;
use crate::util::json::{parse, Value};

pub struct Service {
    ds: Arc<OfflineDataset>,
    backend: Arc<dyn Backend + Send + Sync>,
}

impl Service {
    pub fn new(ds: Arc<OfflineDataset>, backend: Arc<dyn Backend + Send + Sync>) -> Service {
        Service { ds, backend }
    }

    /// Handle one request line; always returns a JSON response line.
    pub fn handle(&self, line: &str) -> String {
        match self.handle_inner(line) {
            Ok(v) => v.to_string_compact(),
            Err(e) => Value::obj(vec![("ok", false.into()), ("error", e.into())])
                .to_string_compact(),
        }
    }

    fn handle_inner(&self, line: &str) -> Result<Value, String> {
        let req = parse(line).map_err(|e| format!("bad json: {e}"))?;
        let op = req.get("op").and_then(|v| v.as_str()).unwrap_or("optimize");
        match op {
            "ping" => Ok(Value::obj(vec![("ok", true.into()), ("pong", true.into())])),
            "list_workloads" => {
                let names: Vec<Value> =
                    self.ds.workloads.iter().map(|w| Value::str(w.id())).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("workloads", Value::Arr(names))]))
            }
            "list_methods" => {
                let names: Vec<Value> =
                    ALL_OPTIMIZERS.iter().map(|m| Value::str(*m)).collect();
                Ok(Value::obj(vec![("ok", true.into()), ("methods", Value::Arr(names))]))
            }
            "optimize" => {
                let workload_id = req
                    .get("workload")
                    .and_then(|v| v.as_str())
                    .ok_or("missing 'workload'")?;
                let workload = self
                    .ds
                    .workload_index(workload_id)
                    .ok_or_else(|| format!("unknown workload '{workload_id}'"))?;
                let target = Target::parse(
                    req.get("target").and_then(|v| v.as_str()).unwrap_or("cost"),
                )
                .ok_or("target must be 'time' or 'cost'")?;
                let method = req
                    .get("method")
                    .and_then(|v| v.as_str())
                    .unwrap_or("cb-rbfopt")
                    .to_string();
                let budget =
                    req.get("budget").and_then(|v| v.as_usize()).unwrap_or(33);
                let seed = req.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                if budget == 0 || budget > 10_000 {
                    return Err("budget out of range".into());
                }
                let trial_workers = match req.get("trial_workers") {
                    None => 1,
                    Some(v) => v
                        .as_usize()
                        .ok_or("trial_workers must be a non-negative integer")?,
                };
                if trial_workers == 0 || trial_workers > MAX_TRIAL_WORKERS {
                    return Err(format!("trial_workers must be in 1..={MAX_TRIAL_WORKERS}"));
                }
                let measure_mode = match req.get("measure_mode") {
                    None => MeasureMode::SingleDraw,
                    Some(v) => {
                        let s = v.as_str().ok_or("measure_mode must be a string")?;
                        MeasureMode::parse(s).ok_or_else(|| {
                            format!("bad measure_mode '{s}' (single_draw | mean | p90)")
                        })?
                    }
                };

                let spec = TrialSpec {
                    method,
                    workload,
                    target,
                    budget,
                    seed,
                    trial_workers,
                    measure_mode,
                };
                let r = run_trial(&self.ds, self.backend.as_ref(), &spec);
                Ok(Value::obj(vec![
                    ("ok", true.into()),
                    ("workload", workload_id.into()),
                    ("target", target.name().into()),
                    ("method", spec.method.as_str().into()),
                    ("value", r.chosen_value.into()),
                    ("regret", r.regret.into()),
                    ("evals", r.evals.into()),
                    ("search_expense", r.search_expense.into()),
                ]))
            }
            other => Err(format!("unknown op '{other}'")),
        }
    }

    /// Serve until `stop` is set. Returns the bound local port.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<(u16, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr)?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;
        let svc = self;
        let handle = std::thread::spawn(move || {
            let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let svc = svc.clone();
                        workers.push(std::thread::spawn(move || {
                            let _ = handle_conn(&svc, stream);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    Err(_) => break,
                }
            }
            for w in workers {
                let _ = w.join();
            }
        });
        Ok((port, handle))
    }
}

fn handle_conn(svc: &Service, stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(300)))?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = svc.handle(&line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surrogate::NativeBackend;

    fn service() -> Service {
        let ds = Arc::new(OfflineDataset::generate(60, 3));
        Service::new(ds, Arc::new(NativeBackend))
    }

    #[test]
    fn ping_and_lists() {
        let svc = service();
        assert!(svc.handle(r#"{"op":"ping"}"#).contains("pong"));
        let w = svc.handle(r#"{"op":"list_workloads"}"#);
        assert!(w.contains("kmeans:santander"), "{w}");
        let m = svc.handle(r#"{"op":"list_methods"}"#);
        assert!(m.contains("cb-rbfopt"), "{m}");
    }

    #[test]
    fn optimize_request_roundtrip() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"xgboost:credit_card","target":"cost","method":"rs","budget":11,"seed":3}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(11));
        assert!(v.get("value").unwrap().as_f64().unwrap() > 0.0);
    }

    /// `trial_workers` changes request latency, never the answer.
    #[test]
    fn parallel_optimize_requests_match_sequential() {
        let svc = service();
        let req = |workers: usize| {
            format!(
                r#"{{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cb-rbfopt","budget":22,"seed":5,"trial_workers":{workers}}}"#
            )
        };
        let seq = svc.handle(&req(1));
        let par = svc.handle(&req(4));
        assert!(seq.contains("\"ok\":true") || seq.contains("\"ok\": true"), "{seq}");
        assert_eq!(seq, par, "trial_workers changed the response");
    }

    #[test]
    fn mean_mode_requests_run_memoized() {
        let svc = service();
        let resp = svc.handle(
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"cost","method":"cherrypick-x1","budget":95,"seed":2,"measure_mode":"mean"}"#,
        );
        let v = parse(&resp).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true), "{resp}");
        assert_eq!(v.get("evals").unwrap().as_usize(), Some(95));
    }

    #[test]
    fn malformed_requests_get_errors_not_panics() {
        let svc = service();
        for bad in [
            "not json",
            r#"{"op":"optimize"}"#,
            r#"{"op":"optimize","workload":"nope:nope"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","target":"speed"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","budget":0}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":0}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":9999}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":"4"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","trial_workers":-2}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":"median"}"#,
            r#"{"op":"optimize","workload":"kmeans:buzz","measure_mode":5}"#,
            r#"{"op":"wat"}"#,
        ] {
            let resp = svc.handle(bad);
            let v = parse(&resp).unwrap();
            assert_eq!(v.get("ok").unwrap().as_bool(), Some(false), "{bad} -> {resp}");
        }
    }

    #[test]
    fn tcp_end_to_end() {
        use std::io::{BufRead, BufReader, Write};
        let svc = Arc::new(service());
        let stop = Arc::new(AtomicBool::new(false));
        let (port, handle) = svc.serve("127.0.0.1:0", stop.clone()).unwrap();
        {
            let mut conn = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            conn.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut line = String::new();
            BufReader::new(conn.try_clone().unwrap()).read_line(&mut line).unwrap();
            assert!(line.contains("pong"), "{line}");
        }
        stop.store(true, Ordering::Relaxed);
        handle.join().unwrap();
    }
}
